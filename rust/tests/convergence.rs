//! Error-feedback convergence matrix (ISSUE 8 satellite).
//!
//! Metamorphic properties of the two-sided EF scheme on the quantized
//! wire, for the three wire-native leaders (flat optinc switch, fabric
//! cascade, hierarchical cascade) at chunk grains {1, 7, len−1, len,
//! len+1}:
//!
//!   (a) EF **on** at bits ∈ {2, 4}: the relative cumulative error of
//!       the streamed mean against the exact f64 mean decays like 1/T —
//!       below `EF_ON_BOUND` after `T_FULL` steps, and at most
//!       `DECAY_MAX` of its value at the `T_MID` checkpoint;
//!   (b) EF **off** at the same widths: the round-half-up word mean's
//!       persistent bias keeps the same error above `EF_OFF_FLOOR`;
//!   (c) EF at bits = 32 is bit-exact to the non-EF path (EF is defined
//!       as structurally inactive at full width).
//!
//! Every streamed step is additionally pinned bit-for-bit against the
//! independent scalar oracles in `quant` (`ChunkedEfReference` /
//! `chunked_reference_mean`) and the vectorized wire codec against
//! `wire::reference`, and a threaded-vs-event cluster run checks the
//! same EF stream end to end across backends. All thresholds were
//! calibrated with ≥2× margin by an f64 simulation of the reference
//! recursion (worst EF-on 4.48e-4 vs bound 1e-3; best EF-off 5.0e-2 vs
//! floor 1e-2; worst decay ratio 0.079 vs bound 0.5). Every assertion
//! message carries the replay seed.

use std::sync::mpsc;

use optinc::cluster::workloads::{synth_exact_mean, synth_grad};
use optinc::cluster::{Backend, Cluster, ClusterMetrics, Workload};
use optinc::collectives::engine::{ChunkedAllReduce, ChunkedDriver, ErrorFeedback};
use optinc::collectives::fabric::{FabricAllReduce, FabricMode, FabricTopology};
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::wire::{pack_quantized_into, reference, unpack_words_into};
use optinc::config::Scenario;
use optinc::optinc::cascade::CascadeMode;
use optinc::quant::{chunked_reference_mean, ChunkedEfReference, GlobalQuantizer};

/// The replay seed: gradients, jitter, and every assertion message
/// derive from this one value.
const SEED: u64 = 0xEF5EED;
/// Gradient length; grains {1, 7, DIM−1, DIM, DIM+1} cover sub-element,
/// ragged, exact, and oversized chunking.
const DIM: usize = 24;
const GRAINS: [usize; 5] = [1, 7, DIM - 1, DIM, DIM + 1];
const BITS: [u32; 2] = [2, 4];
/// Full horizon for the convergence bounds and the decay checkpoint the
/// ratio is measured against.
const T_FULL: usize = 4096;
const T_MID: usize = 256;
/// Steps for the per-grain oracle-equality pass (bit-exactness needs no
/// long horizon).
const T_ORACLE: usize = 256;
/// Calibrated thresholds (see module docs for the measured margins).
const EF_ON_BOUND: f64 = 1e-3;
const EF_OFF_FLOOR: f64 = 1e-2;
const DECAY_MAX: f64 = 0.5;

/// The three wire-native leaders under test, each at its own worker
/// count (5 exercises the fabric's padded group, 4 the flat switch, 8 a
/// two-group cascade).
#[derive(Clone, Copy, Debug)]
enum Leader {
    Fabric,
    OptInc,
    Hierarchical,
}

const LEADERS: [Leader; 3] = [Leader::Fabric, Leader::OptInc, Leader::Hierarchical];

impl Leader {
    fn workers(self) -> usize {
        match self {
            Leader::Fabric => 5,
            Leader::OptInc => 4,
            Leader::Hierarchical => 8,
        }
    }

    fn make(self, bits: u32) -> Box<dyn ChunkedAllReduce> {
        match self {
            Leader::Fabric => {
                let topo = FabricTopology::for_workers(4, self.workers()).unwrap();
                Box::new(FabricAllReduce::exact(bits, &topo, FabricMode::Remainder).unwrap())
            }
            Leader::OptInc => Box::new(OptIncAllReduce::exact(
                Scenario::fabric_level(bits, 4).unwrap(),
                SEED,
            )),
            Leader::Hierarchical => Box::new(HierarchicalOptInc::new(
                Scenario::fabric_level(bits, 4).unwrap(),
                CascadeMode::Remainder,
            )),
        }
    }
}

fn rel_l1(cum_applied: &[f64], cum_exact: &[f64]) -> f64 {
    let num: f64 = cum_applied
        .iter()
        .zip(cum_exact)
        .map(|(a, e)| (a - e).abs())
        .sum();
    let den: f64 = cum_exact.iter().map(|e| e.abs()).sum();
    num / den
}

/// Stream `steps` synthetic rounds through one collective at one grain,
/// pinning every applied step against the matching scalar oracle
/// (`ChunkedEfReference` with EF on, `chunked_reference_mean` with EF
/// off), and return the relative cumulative error at (`T_MID`, `steps`).
fn stream(
    leader: Leader,
    bits: u32,
    ef: ErrorFeedback,
    grain: usize,
    steps: usize,
) -> (f64, f64) {
    let n = leader.workers();
    let mut coll = leader.make(bits);
    coll.set_error_feedback(ef);
    let mut driver = ChunkedDriver::new(grain);
    let mut oracle = ChunkedEfReference::new(bits, grain);
    let mut cum_a = vec![0.0f64; DIM];
    let mut cum_e = vec![0.0f64; DIM];
    let mut err_mid = f64::NAN;
    let ctx = format!(
        "{leader:?} b{bits} ef={} grain={grain} — replay with seed {SEED:#x}",
        ef.enabled
    );
    for t in 0..steps {
        let mut shards: Vec<Vec<f32>> =
            (0..n).map(|w| synth_grad(SEED, t, w, DIM)).collect();
        let want: Vec<u32> = if ef.enabled {
            oracle.step(&shards).iter().map(|v| v.to_bits()).collect()
        } else {
            chunked_reference_mean(&shards, grain, bits)
                .iter()
                .map(|v| v.to_bits())
                .collect()
        };
        driver.all_reduce(coll.as_mut(), &mut shards);
        let got: Vec<u32> = shards[0].iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "{ctx}: step {t} must bit-match the scalar oracle");
        for s in &shards[1..] {
            assert_eq!(
                s, &shards[0],
                "{ctx}: step {t} broadcast must reach every shard identically"
            );
        }
        let exact = synth_exact_mean(SEED, t, n, DIM);
        for i in 0..DIM {
            cum_a[i] += shards[0][i] as f64;
            cum_e[i] += exact[i];
        }
        if t + 1 == T_MID {
            err_mid = rel_l1(&cum_a, &cum_e);
        }
    }
    (err_mid, rel_l1(&cum_a, &cum_e))
}

/// Full-horizon convergence for one leader: EF on must beat the bound
/// and keep decaying; EF off must stay biased. The six (leader, bits)
/// cells cycle through all five grains so every grain runs a full
/// horizon somewhere in the matrix (the per-grain oracle pass below
/// covers the rest bit-exactly).
fn assert_full_horizon(leader: Leader, cell: &mut usize) {
    for bits in BITS {
        let grain = GRAINS[*cell % GRAINS.len()];
        *cell += 1;
        let ctx = format!(
            "{leader:?} b{bits} grain={grain} T={T_FULL} — replay with seed {SEED:#x}"
        );
        let (on_mid, on_full) = stream(leader, bits, ErrorFeedback::on(), grain, T_FULL);
        assert!(
            on_full < EF_ON_BOUND,
            "{ctx}: EF-on cumulative error {on_full:.3e} must fall below {EF_ON_BOUND:.0e}"
        );
        assert!(
            on_full <= DECAY_MAX * on_mid,
            "{ctx}: EF-on error must keep decaying, got {on_full:.3e} at T={T_FULL} \
             vs {on_mid:.3e} at T={T_MID}"
        );
        let (_, off_full) = stream(leader, bits, ErrorFeedback::off(), grain, T_FULL);
        assert!(
            off_full > EF_OFF_FLOOR,
            "{ctx}: EF-off bias {off_full:.3e} must persist above {EF_OFF_FLOOR:.0e}"
        );
        assert!(
            on_full < off_full,
            "{ctx}: EF-on {on_full:.3e} must beat EF-off {off_full:.3e}"
        );
    }
}

#[test]
fn full_horizon_fabric() {
    let mut cell = 0;
    assert_full_horizon(Leader::Fabric, &mut cell);
}

#[test]
fn full_horizon_optinc() {
    let mut cell = 2;
    assert_full_horizon(Leader::OptInc, &mut cell);
}

#[test]
fn full_horizon_hierarchical() {
    let mut cell = 4;
    assert_full_horizon(Leader::Hierarchical, &mut cell);
}

#[test]
fn every_grain_bit_matches_the_scalar_oracles() {
    // The metamorphic grain axis: chunking must not change a single bit
    // of the applied stream, EF on or off, at any width — pinned against
    // the independent `quant` oracles for every (leader, bits, grain).
    for leader in LEADERS {
        for bits in BITS {
            for grain in GRAINS {
                stream(leader, bits, ErrorFeedback::on(), grain, T_ORACLE);
                stream(leader, bits, ErrorFeedback::off(), grain, T_ORACLE);
            }
        }
    }
}

#[test]
fn residuals_persist_across_empty_rounds() {
    // The empty-step protocol (a LocalSGD non-sync round submits
    // zero-length shards): residual state must carry straight through,
    // so a stream with empty rounds interleaved is bit-identical to the
    // same stream without them — and never allocates residual storage
    // for the empty rounds.
    for leader in LEADERS {
        let n = leader.workers();
        let run = |interleave: bool| -> Vec<Vec<u32>> {
            let mut coll = leader.make(2);
            coll.set_error_feedback(ErrorFeedback::on());
            let mut driver = ChunkedDriver::new(7);
            (0..64)
                .map(|t| {
                    if interleave {
                        let mut empty: Vec<Vec<f32>> = vec![Vec::new(); n];
                        driver.all_reduce(coll.as_mut(), &mut empty);
                    }
                    let mut shards: Vec<Vec<f32>> =
                        (0..n).map(|w| synth_grad(SEED, t, w, DIM)).collect();
                    driver.all_reduce(coll.as_mut(), &mut shards);
                    shards[0].iter().map(|v| v.to_bits()).collect()
                })
                .collect()
        };
        assert_eq!(
            run(false),
            run(true),
            "{leader:?}: empty rounds must not disturb EF residuals \
             (replay with seed {SEED:#x})"
        );
    }
}

#[test]
fn bits32_ef_is_bit_exact_to_the_plain_path() {
    // Satellite (c): at full width a quantize→dequantize round trip is
    // not the identity, so EF is defined as structurally inactive —
    // enabling it must not move a single bit.
    for leader in LEADERS {
        for grain in [7usize, DIM] {
            let n = leader.workers();
            let run = |ef: ErrorFeedback| -> Vec<Vec<u32>> {
                let mut coll = leader.make(32);
                coll.set_error_feedback(ef);
                let mut driver = ChunkedDriver::new(grain);
                (0..16)
                    .map(|t| {
                        let mut shards: Vec<Vec<f32>> =
                            (0..n).map(|w| synth_grad(SEED, t, w, DIM)).collect();
                        driver.all_reduce(coll.as_mut(), &mut shards);
                        shards[0].iter().map(|v| v.to_bits()).collect()
                    })
                    .collect()
            };
            assert_eq!(
                run(ErrorFeedback::on()),
                run(ErrorFeedback::off()),
                "{leader:?} grain={grain}: EF at 32 bits must be a structural no-op \
                 (replay with seed {SEED:#x})"
            );
        }
    }
}

#[test]
fn wire_codec_matches_the_scalar_reference_on_the_live_stream() {
    // The vectorized edge codec against `wire::reference`, on the same
    // synthetic traffic the convergence matrix streams: quantize+pack
    // must produce byte-identical buffers and round-trip to the same
    // words, every step, at every width under test.
    for bits in [2u32, 4, 8] {
        let q = GlobalQuantizer::new(bits);
        for t in 0..T_ORACLE {
            let g = synth_grad(SEED, t, t % 5, DIM);
            let views = [g.as_slice()];
            let scale = GlobalQuantizer::global_scale(&views);
            let words = q.quantize_vec(&g, scale);
            let mut fast = Vec::new();
            pack_quantized_into(&g, &q, scale, &mut fast);
            let mut slow = Vec::new();
            reference::pack_scalar(&words, bits, &mut slow);
            assert_eq!(
                fast, slow,
                "b{bits} step {t}: vectorized pack must equal the scalar \
                 reference (seed {SEED:#x})"
            );
            let mut back_fast = vec![0u32; DIM];
            unpack_words_into(&fast, bits, &mut back_fast);
            let mut back_slow = vec![0u32; DIM];
            reference::unpack_scalar(&slow, bits, &mut back_slow);
            assert_eq!(back_fast, words, "b{bits} step {t}: unpack (seed {SEED:#x})");
            assert_eq!(back_slow, words, "b{bits} step {t}: scalar unpack (seed {SEED:#x})");
        }
    }
}

/// Dense synthetic workload for the cluster runs: pure function of
/// (SEED, step, worker); worker 0 ships every applied average back as
/// raw bit patterns.
struct Dense {
    dim: usize,
    tx: mpsc::Sender<(usize, Vec<u32>)>,
}

impl Workload for Dense {
    fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
        (synth_grad(SEED, step, worker, self.dim), 0.0)
    }

    fn apply(&mut self, step: usize, worker: usize, avg: &[f32]) {
        if worker == 0 {
            self.tx
                .send((step, avg.iter().map(|v| v.to_bits()).collect()))
                .ok();
        }
    }
}

fn cluster_stream(backend: Backend, bits: u32, ef: ErrorFeedback, steps: usize) -> Vec<Vec<u32>> {
    let workers = Leader::Fabric.workers();
    let topo = FabricTopology::for_workers(4, workers).unwrap();
    let mut coll = FabricAllReduce::exact(bits, &topo, FabricMode::Remainder).unwrap();
    let (tx, rx) = mpsc::channel();
    let mut metrics = ClusterMetrics::new("convergence");
    Cluster::new(workers)
        .with_chunk_elems(7)
        .with_backend(backend)
        .with_seed(SEED)
        .with_error_feedback(ef)
        .run(
            steps,
            move |_| Dense {
                dim: DIM,
                tx: tx.clone(),
            },
            &mut coll,
            &mut metrics,
        )
        .unwrap();
    let mut applied: Vec<(usize, Vec<u32>)> = rx.try_iter().collect();
    applied.sort_by_key(|(step, _)| *step);
    applied.into_iter().map(|(_, bits)| bits).collect()
}

#[test]
fn cluster_backends_replay_the_ef_stream_bit_exactly() {
    // The same EF stream end to end through real workers: threaded and
    // event backends must agree bit for bit with each other AND with the
    // scalar oracle (which transitively extends the full-horizon bounds
    // above to both backends), and EF must beat the raw quantized mean
    // on the cluster path too.
    let bits = 2;
    let threaded = cluster_stream(Backend::Threaded, bits, ErrorFeedback::on(), T_MID);
    let event = cluster_stream(Backend::Event, bits, ErrorFeedback::on(), T_MID);
    assert_eq!(
        threaded, event,
        "threaded and event EF streams must be bit-exact (seed {SEED:#x})"
    );

    let workers = Leader::Fabric.workers();
    let mut oracle = ChunkedEfReference::new(bits, 7);
    let mut cum_a = vec![0.0f64; DIM];
    let mut cum_e = vec![0.0f64; DIM];
    assert_eq!(event.len(), T_MID, "one applied average per step (seed {SEED:#x})");
    for (t, applied) in event.iter().enumerate() {
        let shards: Vec<Vec<f32>> =
            (0..workers).map(|w| synth_grad(SEED, t, w, DIM)).collect();
        let want: Vec<u32> = oracle.step(&shards).iter().map(|v| v.to_bits()).collect();
        assert_eq!(
            applied, &want,
            "cluster step {t} must bit-match the scalar EF oracle (seed {SEED:#x})"
        );
        let exact = synth_exact_mean(SEED, t, workers, DIM);
        for i in 0..DIM {
            cum_a[i] += f32::from_bits(applied[i]) as f64;
            cum_e[i] += exact[i];
        }
    }
    let err_on = rel_l1(&cum_a, &cum_e);

    let off = cluster_stream(Backend::Event, bits, ErrorFeedback::off(), T_MID);
    assert_eq!(off.len(), T_MID, "one applied average per step (seed {SEED:#x})");
    let mut cum_off = vec![0.0f64; DIM];
    for applied in &off {
        for i in 0..DIM {
            cum_off[i] += f32::from_bits(applied[i]) as f64;
        }
    }
    let err_off = rel_l1(&cum_off, &cum_e);
    assert!(
        err_off > EF_OFF_FLOOR,
        "cluster EF-off bias {err_off:.3e} must persist above {EF_OFF_FLOOR:.0e} \
         (seed {SEED:#x})"
    );
    assert!(
        err_on < 0.5 * err_off,
        "cluster EF-on {err_on:.3e} must at least halve the EF-off error \
         {err_off:.3e} (seed {SEED:#x})"
    );
}
