//! Property-based tests on collective invariants (the proptest-lite
//! harness in util::proptest): agreement, permutation-invariance,
//! idempotence on identical shards, byte-accounting closed forms, and
//! chunked-streaming equivalence with the exact-mean oracle for chunk
//! sizes that do not divide the element count.

use optinc::collectives::engine::ChunkedDriver;
use optinc::collectives::fabric::{FabricAllReduce, FabricMode, FabricTopology};
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::two_tree::TwoTreeAllReduce;
use optinc::collectives::{exact_mean, AllReduce};
use optinc::config::Scenario;
use optinc::optinc::cascade::CascadeMode;
use optinc::quant::{chunked_reference_mean, quantized_mean, GlobalQuantizer};
use optinc::util::proptest::{forall, Config};
use optinc::util::rng::Pcg32;

fn gen_shards(rng: &mut Pcg32, n: usize, max_len: usize) -> Vec<Vec<f32>> {
    let len = 1 + rng.gen_range(max_len as u32) as usize;
    (0..n)
        .map(|_| {
            (0..len)
                .map(|_| (rng.normal() * rng.uniform(0.01, 2.0)) as f32)
                .collect()
        })
        .collect()
}

#[test]
fn prop_all_workers_agree_after_any_collective() {
    forall(
        Config { cases: 60, seed: 1 },
        |rng| gen_shards(rng, 4, 512),
        |shards| {
            let collectives: Vec<Box<dyn AllReduce>> = vec![
                Box::new(RingAllReduce::new()),
                Box::new(TwoTreeAllReduce::new()),
                Box::new(OptIncAllReduce::exact(Scenario::table1(1).unwrap(), 1)),
            ];
            for mut c in collectives {
                let mut work = shards.clone();
                c.all_reduce(&mut work);
                for s in &work[1..] {
                    if s != &work[0] {
                        return Err(format!("{} workers disagree", c.name()));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_optinc_average_is_permutation_invariant() {
    // The switch averages; server order must not matter.
    forall(
        Config { cases: 80, seed: 2 },
        |rng| {
            let shards = gen_shards(rng, 4, 256);
            let perm_seed = rng.next_u64();
            (shards, perm_seed)
        },
        |(shards, perm_seed)| {
            let mut a = shards.clone();
            OptIncAllReduce::exact(Scenario::table1(1).unwrap(), 1).all_reduce(&mut a);
            let mut order: Vec<usize> = (0..4).collect();
            Pcg32::seeded(*perm_seed).shuffle(&mut order);
            let mut permuted: Vec<Vec<f32>> =
                order.iter().map(|&i| shards[i].clone()).collect();
            OptIncAllReduce::exact(Scenario::table1(1).unwrap(), 1).all_reduce(&mut permuted);
            if a[0] == permuted[0] {
                Ok(())
            } else {
                Err("permutation changed the average".into())
            }
        },
    );
}

#[test]
fn prop_identical_shards_are_fixed_points() {
    // Averaging N copies of the same gradient must return it (up to one
    // quantization round-trip for OptINC).
    forall(
        Config { cases: 60, seed: 3 },
        |rng| {
            let len = 1 + rng.gen_range(300) as usize;
            (0..len).map(|_| rng.normal() as f32).collect::<Vec<f32>>()
        },
        |shard| {
            let mut shards: Vec<Vec<f32>> = (0..4).map(|_| shard.clone()).collect();
            RingAllReduce::new().all_reduce(&mut shards);
            for (a, b) in shards[0].iter().zip(shard) {
                if (a - b).abs() > 1e-6 {
                    return Err(format!("ring moved a fixed point: {a} vs {b}"));
                }
            }
            let mut shards: Vec<Vec<f32>> = (0..4).map(|_| shard.clone()).collect();
            let q = GlobalQuantizer::new(8);
            let views: Vec<&[f32]> = shards.iter().map(|s| s.as_slice()).collect();
            let scale = GlobalQuantizer::global_scale(&views);
            OptIncAllReduce::exact(Scenario::table1(1).unwrap(), 1).all_reduce(&mut shards);
            let tol = q.max_abs_error(scale) * 2.0 + 1e-6;
            for (a, b) in shards[0].iter().zip(shard) {
                if (a - b).abs() > tol {
                    return Err(format!("optinc fixed point err {} > {tol}", (a - b).abs()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantized_mean_bounds() {
    // Q(mean) lies within [min, max] of the inputs and matches the
    // round-half-up closed form.
    forall(
        Config { cases: 300, seed: 4 },
        |rng| {
            let n = 1 + rng.gen_range(16) as usize;
            (0..n).map(|_| rng.gen_range(256)).collect::<Vec<u32>>()
        },
        |words| {
            let q = quantized_mean(words);
            let lo = *words.iter().min().unwrap();
            let hi = *words.iter().max().unwrap();
            if q < lo || q > hi {
                return Err(format!("mean {q} outside [{lo}, {hi}]"));
            }
            let f = words.iter().map(|&w| w as f64).sum::<f64>() / words.len() as f64;
            let expect = (f + 0.5).floor() as u32;
            if q != expect {
                return Err(format!("rounding mismatch: {q} vs {expect} (mean {f})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cascade_remainder_equals_flat_for_any_group_count() {
    forall(
        Config { cases: 120, seed: 5 },
        |rng| {
            let groups = 1 + rng.gen_range(4) as usize; // 4..16 servers
            let shards = gen_shards(rng, 4 * groups, 128);
            shards
        },
        |shards| {
            let sc = Scenario::table1(1).unwrap();
            let mut a = shards.clone();
            HierarchicalOptInc::new(sc.clone(), CascadeMode::Remainder).all_reduce(&mut a);
            // Flat reference: quantize + integer mean + dequantize.
            let views: Vec<&[f32]> = shards.iter().map(|s| s.as_slice()).collect();
            let scale = GlobalQuantizer::global_scale(&views);
            let q = GlobalQuantizer::new(8);
            let len = shards[0].len();
            for i in 0..len {
                let words: Vec<u32> =
                    shards.iter().map(|s| q.quantize(s[i], scale)).collect();
                let want = q.dequantize(quantized_mean(&words), scale);
                if (a[0][i] - want).abs() > 1e-6 {
                    return Err(format!("element {i}: {} vs {want}", a[0][i]));
                }
            }
            Ok(())
        },
    );
}

/// The ISSUE-2 satellite matrix: every chunked collective must match
/// `exact_mean` (exactly for ring/two-tree, within quantization
/// tolerance for the OptINC paths) for chunk sizes that do not divide
/// the element count — 1, 7, len−1, len, len+1 — across 2–16 workers.
#[test]
fn prop_chunked_collectives_match_exact_mean() {
    forall(
        Config { cases: 12, seed: 7 },
        |rng| {
            let len = 10 + rng.gen_range(120) as usize;
            (len, rng.next_u64())
        },
        |&(len, seed)| {
            let chunk_sizes = [1usize, 7, len - 1, len, len + 1];
            let mut data_rng = Pcg32::seeded(seed);
            let mut gen = |n: usize| -> Vec<Vec<f32>> {
                (0..n)
                    .map(|_| {
                        (0..len)
                            .map(|_| (data_rng.normal() * 0.2) as f32)
                            .collect()
                    })
                    .collect()
            };

            // Exact collectives: ring (2–16 workers) and two-tree.
            for n in [2usize, 3, 5, 8, 13, 16] {
                let base = gen(n);
                let want = exact_mean(&base);
                for &cs in &chunk_sizes {
                    for flavor in ["ring", "two-tree"] {
                        let mut work = base.clone();
                        let mut driver = ChunkedDriver::new(cs);
                        let stats = match flavor {
                            "ring" => {
                                driver.all_reduce(&mut RingAllReduce::new(), &mut work)
                            }
                            _ => driver
                                .all_reduce(&mut TwoTreeAllReduce::new(), &mut work),
                        };
                        if stats.elements != len {
                            return Err(format!("{flavor}: wrong element count"));
                        }
                        if stats.chunks as usize != len.div_ceil(cs) {
                            return Err(format!("{flavor}: wrong chunk count"));
                        }
                        for (w, s) in work.iter().enumerate() {
                            for (i, (a, b)) in s.iter().zip(&want).enumerate() {
                                if (a - b).abs() > 1e-5 {
                                    return Err(format!(
                                        "{flavor} n={n} chunk={cs} worker={w} \
                                         elem {i}: {a} vs {b}"
                                    ));
                                }
                            }
                        }
                    }
                }
            }

            // Quantized collectives: OptINC flat (N = scenario servers)
            // and the hierarchical cascade (multiples of the fan-in).
            for (sid, n) in [(1usize, 4usize), (2, 8), (3, 16)] {
                let base = gen(n);
                let want = exact_mean(&base);
                let views: Vec<&[f32]> = base.iter().map(|s| s.as_slice()).collect();
                let scale = GlobalQuantizer::global_scale(&views);
                let q = GlobalQuantizer::new(8);
                let tol = q.max_abs_error(scale) * 2.0 + 1e-6;
                for &cs in &chunk_sizes {
                    let mut work = base.clone();
                    let mut driver = ChunkedDriver::new(cs);
                    let mut coll = OptIncAllReduce::exact(Scenario::table1(sid).unwrap(), 1);
                    driver.all_reduce(&mut coll, &mut work);
                    for s in &work[1..] {
                        if s != &work[0] {
                            return Err(format!("optinc n={n} chunk={cs}: disagreement"));
                        }
                    }
                    for (a, b) in work[0].iter().zip(&want) {
                        if (a - b).abs() > tol {
                            return Err(format!(
                                "optinc n={n} chunk={cs}: err {} > tol {tol}",
                                (a - b).abs()
                            ));
                        }
                    }
                    // The packed wire acceptance bar: the pipeline
                    // (edge quantize → pack → word-domain switch →
                    // packed broadcast → dequantize) must be BIT-exact
                    // against the shared flat oracle at every chunk
                    // grain, not merely within tolerance.
                    let exact = chunked_reference_mean(&base, cs, 8);
                    if work[0] != exact {
                        return Err(format!(
                            "optinc n={n} chunk={cs}: packed pipeline drifted \
                             from chunked_reference_mean"
                        ));
                    }
                }
            }
            for n in [8usize, 16] {
                let base = gen(n);
                let want = exact_mean(&base);
                let views: Vec<&[f32]> = base.iter().map(|s| s.as_slice()).collect();
                let scale = GlobalQuantizer::global_scale(&views);
                let q = GlobalQuantizer::new(8);
                let tol = q.max_abs_error(scale) * 4.0 + 1e-6; // two quantized hops
                for &cs in &chunk_sizes {
                    let mut work = base.clone();
                    let mut driver = ChunkedDriver::new(cs);
                    let mut coll = HierarchicalOptInc::new(
                        Scenario::table1(1).unwrap(),
                        CascadeMode::Remainder,
                    );
                    driver.all_reduce(&mut coll, &mut work);
                    for (a, b) in work[0].iter().zip(&want) {
                        if (a - b).abs() > tol {
                            return Err(format!(
                                "cascade n={n} chunk={cs}: err {} > tol {tol}",
                                (a - b).abs()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// The ISSUE-4 oracle-conformance matrix: the remainder-mode fabric must
/// be **bit-exact** against the flat single-switch quantized mean for
/// fan-ins {2, 4, 16} × depths {1, 2, 3} × worker counts that are not
/// powers of the fan-in (ragged last switches at every level) × chunk
/// sizes {1, 7, len−1, len, len+1}.
#[test]
fn prop_fabric_remainder_bit_exact_vs_flat_quantized_mean() {
    let len = 61usize; // prime, so no chunk size divides it
    let chunk_sizes = [1usize, 7, len - 1, len, len + 1];
    let mut data_rng = Pcg32::seeded(0xFAB);

    for &fan_in in &[2usize, 4, 16] {
        for depth in 1..=3usize {
            let topo = FabricTopology::uniform(fan_in, depth).unwrap();
            let cap = topo.capacity();
            // Ragged and aligned populations: full capacity, one short
            // of capacity, a bit more than half, and small odd counts.
            let mut worker_counts = vec![cap, cap - 1, cap / 2 + 1, 3, 5];
            worker_counts.retain(|&w| w >= 2 && w <= cap);
            worker_counts.dedup();
            // Keep the 16^3 = 4096-leaf tree CI-sized.
            worker_counts.retain(|&w| w <= 300);
            if worker_counts.is_empty() {
                worker_counts.push(cap.min(300));
            }

            for &workers in &worker_counts {
                let shards: Vec<Vec<f32>> = (0..workers)
                    .map(|_| {
                        (0..len)
                            .map(|_| (data_rng.normal() * 0.3) as f32)
                            .collect()
                    })
                    .collect();
                for &cs in &chunk_sizes {
                    let want = chunked_reference_mean(&shards, cs, 8);
                    let mut fabric =
                        FabricAllReduce::exact(8, &topo, FabricMode::Remainder).unwrap();
                    let mut work = shards.clone();
                    let mut driver = ChunkedDriver::new(cs);
                    let stats = driver.all_reduce(&mut fabric, &mut work);
                    assert_eq!(stats.chunks as usize, len.div_ceil(cs));
                    assert_eq!(stats.levels as usize, depth);
                    for (w, s) in work.iter().enumerate() {
                        assert_eq!(
                            s, &want,
                            "fan-in {fan_in} depth {depth} workers {workers} \
                             chunk {cs} worker {w}: fabric is not bit-exact"
                        );
                    }
                }
            }
        }
    }
}

/// Sanity companion to the matrix: basic (eq. 9 per level) fabrics with
/// depth ≥ 2 must NOT be bit-exact in general — if they were, the
/// remainder machinery would be untestable dead weight.
#[test]
fn prop_fabric_basic_mode_errs_at_depth() {
    let topo = FabricTopology::uniform(4, 2).unwrap();
    let mut rng = Pcg32::seeded(0xBA51C);
    let shards: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..2000).map(|_| (rng.normal() * 0.3) as f32).collect())
        .collect();
    let want = chunked_reference_mean(&shards, 2000, 8);
    let mut fabric = FabricAllReduce::exact(8, &topo, FabricMode::Basic).unwrap();
    let mut work = shards.clone();
    fabric.all_reduce(&mut work);
    let diffs = work[0].iter().zip(&want).filter(|(a, b)| a != b).count();
    assert!(diffs > 0, "two-level quantization should err on 2000 elements");
}

#[test]
fn prop_ring_byte_accounting_matches_closed_form() {
    forall(
        Config { cases: 60, seed: 6 },
        |rng| {
            let n = 2 + rng.gen_range(15) as usize;
            let chunks = 1 + rng.gen_range(64) as usize;
            (n, n * chunks) // divisible => exact formula
        },
        |&(n, len)| {
            let mut shards: Vec<Vec<f32>> = (0..n).map(|_| vec![1.0; len]).collect();
            let stats = RingAllReduce::new().all_reduce(&mut shards);
            let want = RingAllReduce::bytes_per_server(n, (len * 4) as u64);
            if stats.bytes_sent_per_server == want {
                Ok(())
            } else {
                Err(format!(
                    "N={n} len={len}: {} vs {want}",
                    stats.bytes_sent_per_server
                ))
            }
        },
    );
}
