//! PJRT artifact tests: execute every HLO artifact present in artifacts/
//! and cross-check the switch artifact against the native ONN executor
//! and the arithmetic oracle. Artifact-dependent tests skip (with a
//! message) when `make artifacts` has not run — the handwritten-HLO test
//! always runs.

#![cfg(feature = "pjrt")]

use optinc::config::{artifacts_dir, Scenario};
use optinc::onn::OnnNetwork;
use optinc::optinc::switch::{OnnMode, OptIncSwitch};
use optinc::pam4::{snap_pam4, Pam4Codec};
use optinc::runtime::{lit_f32, lit_i32, lit_scalar_f32, to_f32, Runtime};
use optinc::util::rng::Pcg32;

#[test]
fn handwritten_hlo_roundtrip() {
    let hlo = r#"
HloModule scale, entry_computation_layout={(f32[8]{0})->(f32[8]{0})}

ENTRY main {
  x = f32[8]{0} parameter(0)
  c = f32[] constant(3)
  b = f32[8]{0} broadcast(c), dimensions={}
  m = f32[8]{0} multiply(x, b)
  ROOT t = (f32[8]{0}) tuple(m)
}
"#;
    let rt = Runtime::new().unwrap();
    let exe = rt.compile_text("scale", hlo).unwrap();
    let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
    let out = exe.run(&[lit_f32(&x, &[8]).unwrap()]).unwrap();
    let y = to_f32(&out[0]).unwrap();
    assert_eq!(y, (0..8).map(|i| 3.0 * i as f32).collect::<Vec<_>>());
}

#[test]
fn switch_artifact_matches_native_onn_and_words_stay_in_range() {
    let rt = Runtime::new().unwrap();
    let name = "switch_onn_s1_b4096";
    if !rt.artifact_exists(name) {
        eprintln!("skipping: {name} not built (run `make artifacts`)");
        return;
    }
    let sc = Scenario::table1(1).unwrap();
    let weights = artifacts_dir().join("onn_s1.otsr");
    let net = OnnNetwork::load(&weights).unwrap();
    let m_out = net.output_dim();
    let mut native = OptIncSwitch::new(sc.clone(), OnnMode::Native(net)).unwrap();

    let mut rng = Pcg32::seeded(123);
    let count = 4096usize;
    let shards: Vec<Vec<u32>> = (0..4)
        .map(|_| (0..count).map(|_| rng.gen_range(256)).collect())
        .collect();
    let views: Vec<&[u32]> = shards.iter().map(|s| s.as_slice()).collect();
    let native_avg = native.average_words(&views);

    // PJRT path.
    let exe = rt.load(name).unwrap();
    let m = sc.symbols();
    let codec = Pam4Codec::new(8);
    let mut plane = vec![0.0f32; count * 4 * m];
    let mut sym = vec![0u8; m];
    for (s, shard) in shards.iter().enumerate() {
        for (i, &w) in shard.iter().enumerate() {
            codec.encode_word_into(w, &mut sym);
            for (j, &v) in sym.iter().enumerate() {
                plane[i * 4 * m + s * m + j] = v as f32;
            }
        }
    }
    let out = exe
        .run(&[lit_f32(&plane, &[count, 4, m]).unwrap()])
        .unwrap();
    let levels = to_f32(&out[0]).unwrap();
    assert_eq!(levels.len(), count * m_out);
    let pjrt_avg: Vec<u32> = levels
        .chunks_exact(m_out)
        .map(|f| {
            let mut w = 0u32;
            for &a in f {
                w = (w << 2) | snap_pam4(a) as u32;
            }
            w
        })
        .collect();
    let agree = pjrt_avg
        .iter()
        .zip(&native_avg)
        .filter(|(a, b)| a == b)
        .count();
    assert_eq!(agree, count, "PJRT artifact must match the native executor");
    assert!(pjrt_avg.iter().all(|&w| w < 256));
}

#[test]
fn lm_grad_artifact_runs_and_adam_applies() {
    let rt = Runtime::new().unwrap();
    if !rt.artifact_exists("lm_adam") {
        eprintln!("skipping: lm artifacts not built (run `make artifacts`)");
        return;
    }
    // Load params + manifest-declared shapes indirectly via the trainer.
    use optinc::train::{DpTrainer, WorkloadKind};
    use std::sync::Arc;
    let rt = Arc::new(rt);
    let mut trainer = DpTrainer::new(rt.clone(), WorkloadKind::Lm).unwrap();
    let p0 = trainer.params.clone();
    let mut ring = optinc::collectives::ring::RingAllReduce::new();
    let logs = trainer.run(2, 3, &mut ring, 42, 0).unwrap();
    assert_eq!(logs.len(), 3);
    // Loss should be near ln(vocab) at init and finite.
    assert!(logs[0].mean_loss.is_finite());
    assert!(logs[0].mean_loss < 10.0 && logs[0].mean_loss > 1.0);
    // Parameters moved.
    let moved = trainer
        .params
        .iter()
        .zip(&p0)
        .filter(|(a, b)| a != b)
        .count();
    assert!(moved > p0.len() / 2, "adam should update most parameters");
}

#[test]
fn adam_artifact_matches_reference_formula() {
    let rt = Runtime::new().unwrap();
    if !rt.artifact_exists("lm_adam") {
        eprintln!("skipping: lm artifacts not built");
        return;
    }
    // The artifact's P is fixed; probe with synthetic vectors of that
    // size loaded from the params file.
    let tf = optinc::util::tensorfile::TensorFile::load(
        &artifacts_dir().join("lm_params.otsr"),
    )
    .unwrap();
    let p0 = tf.get("params").unwrap().as_f32().unwrap().to_vec();
    let n = p0.len();
    let exe = rt.load("lm_adam").unwrap();
    let g = vec![0.25f32; n];
    let zeros = vec![0f32; n];
    let out = exe
        .run(&[
            lit_f32(&p0, &[n]).unwrap(),
            lit_f32(&zeros, &[n]).unwrap(),
            lit_f32(&zeros, &[n]).unwrap(),
            lit_scalar_f32(0.0),
            lit_f32(&g, &[n]).unwrap(),
        ])
        .unwrap();
    let p1 = to_f32(&out[0]).unwrap();
    // First Adam step ≈ −lr·sign(g) with lr = 3e-3 (workloads.py).
    let delta = p1[0] - p0[0];
    assert!((delta + 3e-3).abs() < 3e-4, "delta {delta}");
    let _ = lit_i32(&[1, 2], &[2]).unwrap(); // exercise the i32 literal path
}
