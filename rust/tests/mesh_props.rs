//! Property matrix for the unitary-mesh abstraction: the dense Clements
//! array and the butterfly factorization must agree on the contracts the
//! rest of the stack leans on — `to_matrix` orthogonality, `propagate`
//! ≡ matrix·vector, the `(n/2)·log₂n` device count, exact programming on
//! realizable targets, power-of-2 padding, and noise-perturbation
//! monotonicity shared through the `UnitaryMesh` trait.

use optinc::linalg::{random_orthogonal, Mat};
use optinc::photonics::butterfly::{physical_size, ButterflyMesh, FitConfig};
use optinc::photonics::mesh::{MziMesh, UnitaryMesh};
use optinc::photonics::noise::NoiseModel;
use optinc::util::rng::Pcg32;

const SIZES: [usize; 6] = [2, 4, 8, 16, 31, 64];

fn random_input(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn butterfly_to_matrix_is_orthogonal_at_every_size() {
    for n in SIZES {
        let mesh = ButterflyMesh::random(n, 100 + n as u64);
        let q = mesh.to_matrix();
        // Physical matrix (padded for n = 31): structurally orthogonal.
        assert_eq!(q.rows, physical_size(n));
        let err = q.orthogonality_error();
        assert!(err < 1e-12, "n={n}: ‖QᵀQ−I‖ = {err:.3e}");
    }
}

#[test]
fn propagate_agrees_with_matrix_matvec_for_both_kinds() {
    for n in SIZES {
        // Butterfly: random mesh, physical-length input.
        let bf = ButterflyMesh::random(n, 200 + n as u64);
        let x = random_input(bf.size, 300 + n as u64);
        let via_prop = ButterflyMesh::propagate(&bf, &x);
        let via_mat = bf.to_matrix().matvec(&x);
        for (a, b) in via_prop.iter().zip(&via_mat) {
            assert!((a - b).abs() < 1e-11, "butterfly n={n}");
        }

        // Dense: programmed from a random orthogonal target (dense
        // meshes take any n — no padding).
        let mut rng = Pcg32::seeded(400 + n as u64);
        let q = random_orthogonal(&mut rng, n);
        let dense = MziMesh::program(&q, 1e-8).unwrap();
        let x = random_input(n, 500 + n as u64);
        let via_prop = MziMesh::propagate(&dense, &x);
        let via_mat = dense.to_matrix().matvec(&x);
        for (a, b) in via_prop.iter().zip(&via_mat) {
            assert!((a - b).abs() < 1e-9, "dense n={n}");
        }
    }
}

#[test]
fn butterfly_mzi_count_is_half_p_log2_p() {
    for n in SIZES {
        let mesh = ButterflyMesh::random(n, n as u64);
        let p = physical_size(n);
        let want = p / 2 * (p.trailing_zeros() as usize);
        assert_eq!(UnitaryMesh::mzi_count(&mesh), want, "n={n}");
        // And the propagate cost is O(p log p): one rotation per MZI
        // plus the sign bank — count them via the stage structure.
        let rotations: usize = mesh.stages.iter().map(|s| s.thetas.len()).sum();
        assert_eq!(rotations, want, "n={n}");
    }
}

#[test]
fn butterfly_program_is_exact_on_realizable_targets() {
    for n in [2usize, 4, 8, 16, 64] {
        let target = ButterflyMesh::random(n, 600 + n as u64).to_matrix();
        let (back, residual) = ButterflyMesh::program(&target, 1e-9).unwrap();
        assert!(residual < 1e-12, "n={n}: residual {residual:.3e}");
        assert!(back.to_matrix().max_abs_diff(&target) < 1e-9, "n={n}");
    }
}

#[test]
fn padded_logical_view_is_consistent() {
    // n = 31 pads to 32 physical ports; the logical propagate must match
    // the logical matrix exactly, with the dark pad port invisible.
    let peel_only = FitConfig { max_iters: 0, tol: 1e-10 };
    let (mesh, _) = ButterflyMesh::fit(&Mat::identity(31), &peel_only);
    assert_eq!(mesh.size, 32);
    assert_eq!(mesh.logical, 31);
    let x = random_input(31, 7);
    let got = mesh.propagate_logical(&x);
    for (a, b) in got.iter().zip(&x) {
        assert!((a - b).abs() < 1e-12, "identity fit must pass through");
    }

    let rnd = ButterflyMesh::random(31, 9);
    let x = random_input(31, 11);
    let via_prop = rnd.propagate_logical(&x);
    let via_mat = rnd.logical_matrix().matvec(&x);
    for (a, b) in via_prop.iter().zip(&via_mat) {
        assert!((a - b).abs() < 1e-11);
    }
}

/// Shared monotonicity contract: more phase noise ⇒ at least as much
/// matrix deviation, for any `UnitaryMesh` implementation, through the
/// same generic `NoiseModel` entry point the trainer uses.
fn deviation_grows_with_sigma<M: UnitaryMesh + Clone>(mesh: &M, label: &str) {
    let sigmas = [0.001, 0.01, 0.05];
    let devs: Vec<f64> = sigmas
        .iter()
        .map(|&s| NoiseModel::new(s, 0.0, 7).matrix_deviation(mesh))
        .collect();
    for (w, (s_lo, s_hi)) in devs.windows(2).zip(sigmas.windows(2).map(|w| (w[0], w[1]))) {
        assert!(
            w[0] < w[1],
            "{label}: deviation not monotone (σ={s_lo}: {}, σ={s_hi}: {})",
            w[0],
            w[1]
        );
    }
    assert!(devs[0] > 0.0, "{label}: noise must move the matrix");
}

#[test]
fn perturbation_deviation_is_monotone_for_both_kinds() {
    let mut rng = Pcg32::seeded(42);
    let q = random_orthogonal(&mut rng, 16);
    let dense = MziMesh::program(&q, 1e-8).unwrap();
    deviation_grows_with_sigma(&dense, "dense");

    let bf = ButterflyMesh::random(16, 43);
    deviation_grows_with_sigma(&bf, "butterfly");
}
