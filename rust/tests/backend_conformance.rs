//! Cross-backend conformance harness (ISSUE 6 tentpole).
//!
//! The threaded cluster backend is the fidelity oracle; the
//! discrete-event backend must replay its exact wire protocol. This
//! property matrix pins the two **bit-exact on applied averaged
//! gradients** and **equal on accounted stats, observed wire bytes,
//! chunk counts, and sync bytes** across:
//!
//!   collectives {ring, optinc, fabric}
//! × workers     {2, 5, 16}
//! × chunk grain {1, 7, len−1, len, len+1}
//! × wire bits   {4, 8}            (packed collectives)
//!
//! plus the backend-API edge cases (zero workers, empty shard, single
//! element, post-fault reuse) and the deterministic-seeding regression
//! (same seed ⇒ identical `StepRecord` streams). Every assertion
//! message carries the replay seed so a failure reproduces
//! byte-for-byte.

use std::sync::mpsc;

use optinc::cluster::workloads::{is_sync_step, LocalSgd};
use optinc::cluster::{Backend, Cluster, ClusterMetrics, ComputeModel, StepRecord, Workload};
use optinc::collectives::engine::{ChunkedAllReduce, ErrorFeedback};
use optinc::collectives::fabric::FabricAllReduce;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::config::Scenario;
use optinc::util::rng::Pcg32;

/// Gradient length for the matrix: prime, so every grain in
/// {1, 7, len−1, len, len+1} exercises a ragged tail.
const DIM: usize = 97;
const STEPS: usize = 2;
/// The replay seed: gradients, jitter streams, and every assertion
/// message derive from this one value.
const SEED: u64 = 0x0C0F_FEE5;

const WORKER_COUNTS: [usize; 3] = [2, 5, 16];
const GRAINS: [usize; 5] = [1, 7, DIM - 1, DIM, DIM + 1];
const BITS: [u32; 2] = [4, 8];

/// Deterministic synthetic workload: the gradient stream is a pure
/// function of (SEED, step, worker), the loss is integer-valued so its
/// f64 sum is exact in any accumulation order (the two backends fold
/// worker losses in different orders), and every applied average is
/// shipped back to the test as raw f32 bit patterns.
struct Synth {
    dim: usize,
    tx: mpsc::Sender<(usize, usize, Vec<u32>)>,
}

impl Workload for Synth {
    fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
        let mut rng = Pcg32::new(SEED ^ ((step as u64) << 20), worker as u64);
        let g = (0..self.dim).map(|_| rng.normal() as f32 * 0.1).collect();
        let loss = (step * 31 + worker + 1) as f64;
        (g, loss)
    }

    fn apply(&mut self, step: usize, worker: usize, avg: &[f32]) {
        let bits = avg.iter().map(|v| v.to_bits()).collect();
        self.tx.send((step, worker, bits)).ok();
    }
}

type Applied = Vec<(usize, usize, Vec<u32>)>;

fn run_one(
    backend: Backend,
    workers: usize,
    grain: usize,
    dim: usize,
    collective: &mut dyn ChunkedAllReduce,
) -> (Vec<StepRecord>, Applied) {
    let (tx, rx) = mpsc::channel();
    let cluster = Cluster::new(workers)
        .with_chunk_elems(grain)
        .with_backend(backend)
        .with_seed(SEED);
    let mut metrics = ClusterMetrics::new("conformance");
    let records = cluster
        .run(
            STEPS,
            move |_| Synth {
                dim,
                tx: tx.clone(),
            },
            collective,
            &mut metrics,
        )
        .unwrap();
    let mut applied: Applied = rx.try_iter().collect();
    applied.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    (records, applied)
}

/// The conformance property: identically constructed collectives, one
/// per backend, must produce bit-exact applied averages and equal
/// accounting for the same workload.
fn assert_conformant<M>(workers: usize, grain: usize, dim: usize, mut make: M, label: &str)
where
    M: FnMut() -> Box<dyn ChunkedAllReduce>,
{
    let mut oracle = make();
    let mut event = make();
    let (tr, ta) = run_one(Backend::Threaded, workers, grain, dim, oracle.as_mut());
    let (er, ea) = run_one(Backend::Event, workers, grain, dim, event.as_mut());
    let ctx =
        format!("{label}: N={workers} grain={grain} dim={dim} — replay with seed {SEED:#x}");

    assert_eq!(
        ta.len(),
        workers * STEPS,
        "{ctx}: every worker applies every step"
    );
    assert_eq!(ta, ea, "{ctx}: applied averages must be bit-exact");
    assert_eq!(tr.len(), er.len(), "{ctx}: step counts");
    for (t, e) in tr.iter().zip(&er) {
        let step = t.step;
        assert_eq!(step, e.step, "{ctx}");
        // CollectiveStats derives PartialEq: bytes, sync bytes, rounds,
        // chunks, elements, overlap, levels — all in one comparison.
        assert_eq!(t.stats, e.stats, "{ctx} step {step}: accounted stats");
        assert_eq!(
            t.observed_wire_bytes_per_server, e.observed_wire_bytes_per_server,
            "{ctx} step {step}: observed wire bytes"
        );
        assert_eq!(t.mean_loss, e.mean_loss, "{ctx} step {step}: mean loss");
        assert_eq!(
            t.modeled_comm_s, e.modeled_comm_s,
            "{ctx} step {step}: modeled step time"
        );
        // And the one sanctioned difference: only the event backend
        // carries a virtual clock.
        assert!(t.virtual_time_s.is_none(), "{ctx}: threaded has no clock");
        assert!(e.virtual_time_s.is_some(), "{ctx}: event must measure");
    }
}

#[test]
fn matrix_ring() {
    // Ring is f32-native: the bits axis does not apply.
    for workers in WORKER_COUNTS {
        for grain in GRAINS {
            assert_conformant(workers, grain, DIM, || Box::new(RingAllReduce::new()), "ring");
        }
    }
}

#[test]
fn matrix_optinc() {
    // One switch sized exactly to the worker count:
    // `Scenario::fabric_level` serves any (even bits, fan-in ≥ 2) pair.
    for workers in WORKER_COUNTS {
        for grain in GRAINS {
            for bits in BITS {
                assert_conformant(
                    workers,
                    grain,
                    DIM,
                    || {
                        Box::new(OptIncAllReduce::exact(
                            Scenario::fabric_level(bits, workers).unwrap(),
                            5,
                        ))
                    },
                    &format!("optinc b{bits}"),
                );
            }
        }
    }
}

#[test]
fn matrix_fabric() {
    // Multi-level cascade of 4-port switches (depth grows with the
    // worker count: 1 level at N=2, 2 levels at N=5 and N=16).
    for workers in WORKER_COUNTS {
        for grain in GRAINS {
            for bits in BITS {
                assert_conformant(
                    workers,
                    grain,
                    DIM,
                    || Box::new(FabricAllReduce::for_workers(bits, 4, workers).unwrap()),
                    &format!("fabric b{bits}"),
                );
            }
        }
    }
}

/// Like [`run_one`] but with a caller-chosen step count, error-feedback
/// policy, and workload factory — the EF and LocalSGD axes need longer
/// horizons (residuals only matter across steps) and stateful per-worker
/// models.
fn run_custom<W, F>(
    backend: Backend,
    workers: usize,
    grain: usize,
    steps: usize,
    ef: ErrorFeedback,
    make_workload: F,
    collective: &mut dyn ChunkedAllReduce,
) -> Vec<StepRecord>
where
    W: Workload,
    F: Fn(usize) -> W,
{
    let cluster = Cluster::new(workers)
        .with_chunk_elems(grain)
        .with_backend(backend)
        .with_seed(SEED)
        .with_error_feedback(ef);
    let mut metrics = ClusterMetrics::new("conformance");
    cluster
        .run(steps, make_workload, collective, &mut metrics)
        .unwrap()
}

/// Error-feedback conformance: with EF residuals live on both the
/// worker and the leader side, the threaded and event backends must
/// still replay each other bit for bit — applied averages, accounted
/// stats, observed wire bytes — across the full worker × grain × bits
/// matrix, over enough steps for residual state to matter.
#[test]
fn matrix_error_feedback() {
    const EF_STEPS: usize = 4;
    for workers in WORKER_COUNTS {
        for grain in GRAINS {
            for bits in BITS {
                let ctx = format!(
                    "fabric-ef b{bits}: N={workers} grain={grain} — replay with seed {SEED:#x}"
                );
                let mut streams = Vec::new();
                for backend in [Backend::Threaded, Backend::Event] {
                    let mut coll = FabricAllReduce::for_workers(bits, 4, workers).unwrap();
                    let (tx, rx) = mpsc::channel();
                    let records = run_custom(
                        backend,
                        workers,
                        grain,
                        EF_STEPS,
                        ErrorFeedback::on(),
                        move |_| Synth {
                            dim: DIM,
                            tx: tx.clone(),
                        },
                        &mut coll,
                    );
                    let mut applied: Applied = rx.try_iter().collect();
                    applied.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
                    streams.push((records, applied));
                }
                let (tr, ta) = &streams[0];
                let (er, ea) = &streams[1];
                assert_eq!(
                    ta.len(),
                    workers * EF_STEPS,
                    "{ctx}: every worker applies every step"
                );
                assert_eq!(ta, ea, "{ctx}: EF applied averages must be bit-exact");
                for (t, e) in tr.iter().zip(er) {
                    assert_eq!(t.stats, e.stats, "{ctx} step {}: accounted stats", t.step);
                    assert_eq!(
                        t.observed_wire_bytes_per_server, e.observed_wire_bytes_per_server,
                        "{ctx} step {}: observed wire bytes",
                        t.step
                    );
                }
            }
        }
    }
}

/// EF must actually move the stream at low bit widths (guards against a
/// silently disconnected residual path passing the conformance matrix
/// by being a no-op).
#[test]
fn error_feedback_changes_the_low_bit_stream() {
    let run = |ef: ErrorFeedback| -> Applied {
        let mut coll = FabricAllReduce::for_workers(4, 4, 5).unwrap();
        let (tx, rx) = mpsc::channel();
        run_custom(
            Backend::Event,
            5,
            7,
            4,
            ef,
            move |_| Synth {
                dim: DIM,
                tx: tx.clone(),
            },
            &mut coll,
        );
        let mut applied: Applied = rx.try_iter().collect();
        applied.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        applied
    };
    assert_ne!(
        run(ErrorFeedback::on()),
        run(ErrorFeedback::off()),
        "EF on a 4-bit wire must change the applied stream (seed {SEED:#x})"
    );
}

/// LocalSGD workload that ships, after every apply, the applied average
/// AND the resulting model, both as raw bit patterns — so conformance
/// covers the workload state the sync actually produces, not just the
/// wire.
struct TapSgd {
    inner: LocalSgd,
    tx: mpsc::Sender<(usize, usize, Vec<u32>)>,
}

impl Workload for TapSgd {
    fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
        self.inner.grad(step, worker)
    }

    fn apply(&mut self, step: usize, worker: usize, avg: &[f32]) {
        self.inner.apply(step, worker, avg);
        let mut rec: Vec<u32> = avg.iter().map(|v| v.to_bits()).collect();
        rec.extend(self.inner.model().iter().map(|v| v.to_bits()));
        self.tx.send((step, worker, rec)).ok();
    }
}

/// LocalSGD conformance: sync period τ ∈ {1, 4} (τ=1 degenerates to
/// every-step sync; τ=4 interleaves three empty non-sync rounds between
/// syncs), with EF both off and on. Applied deltas and post-apply
/// models must be bit-exact across backends, and the per-step byte
/// accounting must show traffic exactly on the sync steps.
#[test]
fn matrix_localsgd_sync_period() {
    const SGD_STEPS: usize = 8;
    const BITS_SGD: u32 = 4;
    for tau in [1usize, 4] {
        for workers in [2usize, 5] {
            for grain in [1usize, 7, DIM] {
                for ef in [ErrorFeedback::off(), ErrorFeedback::on()] {
                    let ctx = format!(
                        "localsgd tau={tau} ef={} b{BITS_SGD}: N={workers} grain={grain} \
                         — replay with seed {SEED:#x}",
                        ef.enabled
                    );
                    let mut streams = Vec::new();
                    for backend in [Backend::Threaded, Backend::Event] {
                        let mut coll =
                            FabricAllReduce::for_workers(BITS_SGD, 4, workers).unwrap();
                        let (tx, rx) = mpsc::channel();
                        let records = run_custom(
                            backend,
                            workers,
                            grain,
                            SGD_STEPS,
                            ef,
                            move |w| TapSgd {
                                inner: LocalSgd::new(w, DIM, tau, SEED),
                                tx: tx.clone(),
                            },
                            &mut coll,
                        );
                        let mut applied: Applied = rx.try_iter().collect();
                        applied.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
                        streams.push((records, applied));
                    }
                    let (tr, ta) = &streams[0];
                    let (er, ea) = &streams[1];
                    assert_eq!(
                        ta.len(),
                        workers * SGD_STEPS,
                        "{ctx}: every worker applies every step"
                    );
                    assert_eq!(
                        ta, ea,
                        "{ctx}: applied deltas and models must be bit-exact"
                    );
                    for (t, e) in tr.iter().zip(er) {
                        let step = t.step;
                        assert_eq!(t.stats, e.stats, "{ctx} step {step}: accounted stats");
                        assert_eq!(
                            t.observed_wire_bytes_per_server,
                            e.observed_wire_bytes_per_server,
                            "{ctx} step {step}: observed wire bytes"
                        );
                        assert_eq!(t.mean_loss, e.mean_loss, "{ctx} step {step}: mean loss");
                        // Traffic exactly on sync rounds: non-sync rounds
                        // run the empty-step protocol (no payload).
                        if is_sync_step(step, tau) {
                            assert!(
                                t.stats.bytes_sent_per_server > 0,
                                "{ctx} step {step}: sync round must move bytes"
                            );
                        } else {
                            assert_eq!(
                                t.stats.bytes_sent_per_server, 0,
                                "{ctx} step {step}: non-sync round must be empty"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn edge_empty_shard_conforms() {
    // Zero-length gradients run the empty-step protocol (one empty
    // chunk, no scale exchange, no reduce) identically on both
    // backends, on both wires.
    for workers in [2usize, 5] {
        assert_conformant(workers, 4, 0, || Box::new(RingAllReduce::new()), "ring empty");
        assert_conformant(
            workers,
            4,
            0,
            || {
                Box::new(OptIncAllReduce::exact(
                    Scenario::fabric_level(8, workers).unwrap(),
                    5,
                ))
            },
            "optinc empty",
        );
    }
}

#[test]
fn edge_single_element_single_chunk_conforms() {
    // The smallest non-empty step: one element, one chunk.
    for workers in WORKER_COUNTS {
        assert_conformant(workers, 1, 1, || Box::new(RingAllReduce::new()), "ring 1-elem");
        assert_conformant(
            workers,
            1,
            1,
            || {
                Box::new(OptIncAllReduce::exact(
                    Scenario::fabric_level(8, workers).unwrap(),
                    5,
                ))
            },
            "optinc 1-elem",
        );
    }
}

#[test]
fn edge_zero_workers_same_error_on_both_backends() {
    for backend in [Backend::Threaded, Backend::Event] {
        let mut ring = RingAllReduce::new();
        let mut metrics = ClusterMetrics::new("none");
        let (tx, _rx) = mpsc::channel();
        let err = Cluster::new(0)
            .with_backend(backend)
            .run(
                1,
                move |_| Synth {
                    dim: 4,
                    tx: tx.clone(),
                },
                &mut ring,
                &mut metrics,
            )
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("at least one worker"),
            "{backend:?}: {err}"
        );
    }
}

/// Workload that panics on one worker at one step — the deterministic
/// fault model shared by both backends.
struct PanicAt {
    dim: usize,
    victim: usize,
    at_step: usize,
    tx: mpsc::Sender<(usize, usize, Vec<u32>)>,
}

impl Workload for PanicAt {
    fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
        if step == self.at_step && worker == self.victim {
            panic!("injected fault: worker {worker} dies at step {step}");
        }
        Synth {
            dim: self.dim,
            tx: self.tx.clone(),
        }
        .grad(step, worker)
    }

    fn apply(&mut self, step: usize, worker: usize, avg: &[f32]) {
        let bits = avg.iter().map(|v| v.to_bits()).collect();
        self.tx.send((step, worker, bits)).ok();
    }
}

#[test]
fn edge_post_fault_reuse_is_identical_on_both_backends() {
    // A collective that survived a failed run must be fully reusable
    // (its next `begin` resets the aborted session), and the post-fault
    // results must still conform across backends.
    let workers = 4usize;
    let fault_run = |backend: Backend, collective: &mut dyn ChunkedAllReduce| -> String {
        let (tx, _rx) = mpsc::channel();
        let cluster = Cluster::new(workers)
            .with_chunk_elems(7)
            .with_backend(backend)
            .with_seed(SEED)
            .with_watchdog(std::time::Duration::from_millis(300));
        let mut metrics = ClusterMetrics::new("fault");
        cluster
            .run(
                3,
                move |_| PanicAt {
                    dim: 20,
                    victim: 2,
                    at_step: 1,
                    tx: tx.clone(),
                },
                collective,
                &mut metrics,
            )
            .unwrap_err()
            .to_string()
    };

    let mut oracle: Box<dyn ChunkedAllReduce> =
        Box::new(OptIncAllReduce::exact(Scenario::fabric_level(8, workers).unwrap(), 5));
    let mut event: Box<dyn ChunkedAllReduce> =
        Box::new(OptIncAllReduce::exact(Scenario::fabric_level(8, workers).unwrap(), 5));

    let te = fault_run(Backend::Threaded, oracle.as_mut());
    assert!(
        te.contains("watchdog") || te.contains("dropped") || te.contains("panicked"),
        "threaded fault must surface cleanly: {te}"
    );
    let ee = fault_run(Backend::Event, event.as_mut());
    assert!(
        ee.contains("watchdog") && ee.contains("panicked"),
        "event fault must name the watchdog and the panic: {ee}"
    );
    assert!(
        ee.contains("virtual deadline"),
        "event fault must carry its deterministic virtual deadline: {ee}"
    );

    // Reuse both collectives for a clean run and re-check conformance.
    let (tr, ta) = run_one(Backend::Threaded, workers, 7, 20, oracle.as_mut());
    let (er, ea) = run_one(Backend::Event, workers, 7, 20, event.as_mut());
    assert_eq!(ta, ea, "post-fault applied averages (replay seed {SEED:#x})");
    for (t, e) in tr.iter().zip(&er) {
        assert_eq!(t.stats, e.stats, "post-fault step {} stats", t.step);
        assert_eq!(
            t.observed_wire_bytes_per_server, e.observed_wire_bytes_per_server,
            "post-fault step {} observed bytes",
            t.step
        );
    }
}

#[test]
fn same_seed_event_runs_produce_identical_step_record_streams() {
    // The deterministic-seeding satellite: with compute jitter switched
    // on, two event runs from the same seed must yield an identical
    // `StepRecord` stream (PartialEq covers the virtual clock too), and
    // a different seed must not.
    let run_with = |seed: u64| -> Vec<StepRecord> {
        let (tx, _rx) = mpsc::channel();
        let mut coll = FabricAllReduce::for_workers(8, 4, 5).unwrap();
        let mut metrics = ClusterMetrics::new("replay");
        Cluster::new(5)
            .with_chunk_elems(7)
            .with_backend(Backend::Event)
            .with_seed(seed)
            .with_compute(ComputeModel::default().with_base_s(1e-6).with_jitter(0.3))
            .run(
                3,
                move |_| Synth {
                    dim: DIM,
                    tx: tx.clone(),
                },
                &mut coll,
                &mut metrics,
            )
            .unwrap()
    };
    let a = run_with(SEED);
    let b = run_with(SEED);
    assert_eq!(a, b, "same seed {SEED:#x} must replay byte-for-byte");
    let c = run_with(SEED ^ 1);
    assert_ne!(
        a.iter()
            .map(|r| r.virtual_time_s.unwrap().to_bits())
            .collect::<Vec<_>>(),
        c.iter()
            .map(|r| r.virtual_time_s.unwrap().to_bits())
            .collect::<Vec<_>>(),
        "a different seed must draw different jitter"
    );
}
