//! Cross-module integration tests: the OptINC datapath end to end
//! (quantize → encode → P → ONN/oracle → snap → decode → dequantize),
//! photonics compile path on real ONN shapes, and the cluster driver
//! with the OptINC collective.

use std::time::Duration;

use optinc::cluster::workloads::synth_grad;
use optinc::cluster::{Backend, Cluster, ClusterMetrics, Workload};
use optinc::collectives::engine::{ChunkedAllReduce, ErrorFeedback};
use optinc::collectives::fabric::FabricAllReduce;
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::{exact_mean, AllReduce};
use optinc::config::Scenario;
use optinc::linalg::Mat;
use optinc::optinc::cascade::CascadeMode;
use optinc::photonics::approx::ApproxMatrix;
use optinc::photonics::mesh::MziMesh;
use optinc::quant::GlobalQuantizer;
use optinc::util::rng::Pcg32;

fn random_shards(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.08).collect())
        .collect()
}

#[test]
fn optinc_collective_tracks_ring_within_quantization_floor() {
    // The central functional claim: OptINC's one-traversal average equals
    // the exact ring average up to the B-bit quantization error.
    for (sid, n) in [(1usize, 4usize), (2, 8), (4, 4)] {
        let sc = Scenario::table1(sid).unwrap();
        let base = random_shards(n, 20_000, sid as u64);
        let want = exact_mean(&base);
        let views: Vec<&[f32]> = base.iter().map(|s| s.as_slice()).collect();
        let scale = GlobalQuantizer::global_scale(&views);

        let mut ring_shards = base.clone();
        RingAllReduce::new().all_reduce(&mut ring_shards);
        let mut oi_shards = base.clone();
        let mut oi = OptIncAllReduce::exact(sc, 1);
        oi.all_reduce(&mut oi_shards);

        let q = GlobalQuantizer::new(if sid == 4 { 16 } else { 8 });
        let tol = q.max_abs_error(scale) * 2.0 + 1e-6;
        for (a, b) in oi_shards[0].iter().zip(&want) {
            assert!((a - b).abs() <= tol, "scenario {sid}: {a} vs {b} tol {tol}");
        }
        // Ring is exact.
        for (a, b) in ring_shards[0].iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5);
        }
    }
}

#[test]
fn trained_onn_weights_map_onto_mzi_meshes() {
    // Photonics compile path on a scenario-1-shaped approximated layer:
    // project → per-block Σ·U → program U onto a mesh → propagate and
    // compare against the dense matvec.
    let mut rng = Pcg32::seeded(31);
    let w = optinc::linalg::random_mat(&mut rng, 64, 64);
    let approx = ApproxMatrix::from_dense(&w);
    assert_eq!(approx.blocks.len(), 1);
    let block = &approx.blocks[0];
    let mesh = MziMesh::program(&block.u, 1e-7).unwrap();
    assert_eq!(mesh.mzi_count(), 64 * 63 / 2);

    let x: Vec<f64> = (0..64).map(|i| ((i * 7) % 13) as f64 * 0.25).collect();
    let through_mesh: Vec<f64> = mesh
        .propagate(&x)
        .iter()
        .zip(&block.d)
        .map(|(y, d)| y * d)
        .collect();
    let dense = approx.to_matrix().matvec(&x);
    for (a, b) in through_mesh.iter().zip(&dense) {
        assert!((a - b).abs() < 1e-8, "{a} vs {b}");
    }
}

#[test]
fn cluster_training_converges_with_optinc_collective() {
    // A linear-regression workload trained data-parallel through the
    // exact-oracle OptINC switch must converge like ring does.
    struct LinReg {
        w: Vec<f32>,
        rng: Pcg32,
    }

    impl Workload for LinReg {
        fn grad(&mut self, _step: usize, worker: usize) -> (Vec<f32>, f64) {
            // True weights = [1, -2, 3, 0.5, ...]; squared loss gradient
            // on a fresh random sample.
            let dim = self.w.len();
            let true_w: Vec<f32> = (0..dim).map(|i| ((i % 7) as f32 - 3.0) * 0.5).collect();
            let mut g = vec![0.0f32; dim];
            let mut loss = 0.0f64;
            let batch = 16;
            for _ in 0..batch {
                let x: Vec<f32> = (0..dim).map(|_| self.rng.normal() as f32).collect();
                let y: f32 = x.iter().zip(&true_w).map(|(a, b)| a * b).sum();
                let pred: f32 = x.iter().zip(&self.w).map(|(a, b)| a * b).sum();
                let err = pred - y;
                loss += (err * err) as f64;
                for (gi, xi) in g.iter_mut().zip(&x) {
                    *gi += 2.0 * err * xi / batch as f32;
                }
            }
            let _ = worker;
            (g, loss / batch as f64)
        }

        fn apply(&mut self, _step: usize, _worker: usize, avg: &[f32]) {
            for (w, g) in self.w.iter_mut().zip(avg) {
                *w -= 0.05 * g;
            }
        }
    }

    let run = |coll: &mut dyn ChunkedAllReduce| -> (f64, f64) {
        // Stream in small chunks so the pipelined path is exercised on a
        // real convergence run (dim 32 → 8 chunks of 4).
        let cluster = Cluster::new(4).with_chunk_elems(4);
        let mut metrics = ClusterMetrics::new("linreg");
        let records = cluster
            .run(
                60,
                |w| LinReg {
                    w: vec![0.0; 32],
                    rng: Pcg32::seeded(100 + w as u64),
                },
                coll,
                &mut metrics,
            )
            .unwrap();
        (records[0].mean_loss, records.last().unwrap().mean_loss)
    };

    let (ring_first, ring_last) = run(&mut RingAllReduce::new());
    let sc = Scenario::table1(4).unwrap(); // 16-bit for a tight floor
    let (oi_first, oi_last) = run(&mut OptIncAllReduce::exact(sc, 3));

    assert!(ring_last < ring_first * 0.05, "ring: {ring_first} -> {ring_last}");
    assert!(oi_last < oi_first * 0.05, "optinc: {oi_first} -> {oi_last}");
    // Final quality comparable (within 5x — both near the noise floor).
    assert!(oi_last < ring_last * 5.0 + 1e-3);
}

#[test]
fn cascade_collective_equals_flat_switch_on_cluster_gradients() {
    let base = random_shards(16, 5_000, 77);
    let sc4 = Scenario::table1(1).unwrap();
    let sc16 = Scenario::table1(3).unwrap();

    let mut a = base.clone();
    HierarchicalOptInc::new(sc4, CascadeMode::Remainder).all_reduce(&mut a);
    let mut b = base.clone();
    OptIncAllReduce::exact(sc16, 1).all_reduce(&mut b);
    assert_eq!(a[0], b[0]);
}

#[test]
fn fabric_collective_runs_beyond_port_count_on_the_cluster() {
    // The scale-out path end to end: 16 workers (4× one switch's ports)
    // of real threaded gradient streams through a depth-2 fabric, and
    // the result is bit-identical to what the flat quantized mean gives.
    struct Probe {
        dim: usize,
        tx: std::sync::mpsc::Sender<(usize, Vec<f32>)>,
    }
    impl Workload for Probe {
        fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
            let mut rng = Pcg32::seeded((step * 100 + worker) as u64);
            let g = (0..self.dim).map(|_| rng.normal() as f32 * 0.1).collect();
            (g, 0.0)
        }
        fn apply(&mut self, _step: usize, worker: usize, avg: &[f32]) {
            self.tx.send((worker, avg.to_vec())).ok();
        }
    }

    let workers = 16usize;
    let (tx, rx) = std::sync::mpsc::channel();
    let cluster = Cluster::new(workers).with_chunk_elems(23);
    let mut fabric = FabricAllReduce::for_workers(8, 4, workers).unwrap();
    assert_eq!(fabric.depth(), 2);
    let mut metrics = ClusterMetrics::new("fabric");
    let records = cluster
        .run(
            1,
            move |_| Probe {
                dim: 100,
                tx: tx.clone(),
            },
            &mut fabric,
            &mut metrics,
        )
        .unwrap();
    assert_eq!(records[0].stats.levels, 2);
    assert_eq!(records[0].stats.rounds, 2);

    // Every worker applied one identical average.
    let mut applied: Vec<(usize, Vec<f32>)> = rx.try_iter().collect();
    applied.sort_by_key(|(w, _)| *w);
    assert_eq!(applied.len(), workers);
    for (_, avg) in &applied[1..] {
        assert_eq!(avg, &applied[0].1);
    }
    // …equal to the flat quantized mean over the same chunk boundaries.
    let shards: Vec<Vec<f32>> = (0..workers)
        .map(|w| {
            let mut rng = Pcg32::seeded(w as u64);
            (0..100).map(|_| rng.normal() as f32 * 0.1).collect()
        })
        .collect();
    let want = optinc::quant::chunked_reference_mean(&shards, 23, 8);
    assert_eq!(applied[0].1, want, "threaded fabric must match the flat oracle");
}

/// The ISSUE-5 acceptance bar: for the packed-wire OptINC and fabric
/// paths, the bytes the leader observes crossing the worker↔leader
/// channels must equal `bytes_sent_per_server + sync_bytes_per_server`
/// — the wire and the accounting agree — and the applied averages must
/// be bit-exact against the shared flat oracle.
#[test]
fn packed_wire_bytes_observed_equal_accounted_for_optinc_and_fabric() {
    struct Probe {
        dim: usize,
        tx: std::sync::mpsc::Sender<(usize, Vec<f32>)>,
    }
    impl Workload for Probe {
        fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
            let mut rng = Pcg32::seeded((step * 1000 + worker) as u64);
            let g = (0..self.dim).map(|_| rng.normal() as f32 * 0.1).collect();
            (g, 0.0)
        }
        fn apply(&mut self, _step: usize, worker: usize, avg: &[f32]) {
            self.tx.send((worker, avg.to_vec())).ok();
        }
    }

    // (name, collective, workers, bits) — flat 8-bit, flat 16-bit, and
    // a depth-2 fabric with a ragged chunk grain.
    let cases: Vec<(&str, Box<dyn ChunkedAllReduce>, usize, u32)> = vec![
        (
            "optinc8",
            Box::new(OptIncAllReduce::exact(Scenario::table1(1).unwrap(), 1)),
            4,
            8,
        ),
        (
            "optinc16",
            Box::new(OptIncAllReduce::exact(Scenario::table1(4).unwrap(), 1)),
            4,
            16,
        ),
        (
            "fabric",
            Box::new(FabricAllReduce::for_workers(8, 4, 16).unwrap()),
            16,
            8,
        ),
        (
            "cascade",
            Box::new(HierarchicalOptInc::new(
                Scenario::table1(1).unwrap(),
                CascadeMode::Remainder,
            )),
            16,
            8,
        ),
    ];
    let dim = 1000usize;
    let chunk = 301usize; // 4 chunks, ragged tail of 97
    for (name, mut coll, workers, bits) in cases {
        let (tx, rx) = std::sync::mpsc::channel();
        let cluster = Cluster::new(workers).with_chunk_elems(chunk);
        let mut metrics = ClusterMetrics::new(name);
        let records = cluster
            .run(
                2,
                move |_| Probe {
                    dim,
                    tx: tx.clone(),
                },
                coll.as_mut(),
                &mut metrics,
            )
            .unwrap();

        let nchunks = dim.div_ceil(chunk) as u64;
        for r in &records {
            // Accounted: B/8 per element payload + (4 + B/8) sync per chunk.
            assert_eq!(
                r.stats.bytes_sent_per_server,
                (dim as u64 * bits as u64).div_ceil(8),
                "{name} step {}",
                r.step
            );
            assert_eq!(
                r.stats.sync_bytes_per_server,
                nchunks * (4 + (bits as u64).div_ceil(8)),
                "{name} step {}",
                r.step
            );
            // Observed == accounted: the wire-format bug is closed.
            assert_eq!(
                r.observed_wire_bytes_per_server,
                r.stats.bytes_sent_per_server + r.stats.sync_bytes_per_server,
                "{name} step {}: observed channel bytes diverge from accounting",
                r.step
            );
        }
        assert_eq!(
            metrics.total_observed_wire_bytes(),
            metrics.total_bytes_per_server(),
            "{name}: run-level observed vs accounted"
        );

        // Bit-exactness of the threaded packed pipeline against the
        // shared flat oracle, chunk boundaries mirrored.
        let mut applied: Vec<(usize, Vec<f32>)> = rx.try_iter().collect();
        applied.retain(|(w, _)| *w == 0);
        assert_eq!(applied.len(), 2, "{name}: worker 0 applied 2 steps");
        for (step, (_, avg)) in applied.iter().enumerate() {
            let shards: Vec<Vec<f32>> = (0..workers)
                .map(|w| {
                    let mut rng = Pcg32::seeded((step * 1000 + w) as u64);
                    (0..dim).map(|_| rng.normal() as f32 * 0.1).collect()
                })
                .collect();
            let want = optinc::quant::chunked_reference_mean(&shards, chunk, bits);
            assert_eq!(
                avg, &want,
                "{name} step {step}: packed pipeline is not bit-exact"
            );
        }
    }
}

/// Fault injection (ISSUE 4 satellite, re-anchored by ISSUE 6): a
/// worker that panics mid-run must surface as a clean `Err` — no
/// deadlock — for both the ring and the fabric collective, on BOTH
/// backends, and the collective must stay usable afterwards (no
/// poisoned pool/session). The watchdog guarantee itself is asserted on
/// the event backend, where the deadline is an exact virtual-time value
/// rather than a bounded wall-clock `elapsed` that flakes on loaded CI
/// boxes.
#[test]
fn panicking_worker_surfaces_clean_err_without_deadlock() {
    struct PanicAt {
        dim: usize,
        victim: usize,
        at_step: usize,
    }
    impl Workload for PanicAt {
        fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
            if worker == self.victim && step == self.at_step {
                panic!("injected worker fault (test)");
            }
            (vec![1.0; self.dim], 0.0)
        }
        fn apply(&mut self, _step: usize, _worker: usize, _avg: &[f32]) {}
    }
    struct Clean {
        dim: usize,
    }
    impl Workload for Clean {
        fn grad(&mut self, _step: usize, _worker: usize) -> (Vec<f32>, f64) {
            (vec![1.0; self.dim], 0.0)
        }
        fn apply(&mut self, _step: usize, _worker: usize, _avg: &[f32]) {}
    }

    let workers = 8usize;
    let watchdog = Duration::from_millis(300);
    for backend in [Backend::Threaded, Backend::Event] {
        let collectives: Vec<Box<dyn ChunkedAllReduce>> = vec![
            Box::new(RingAllReduce::new()),
            Box::new(FabricAllReduce::for_workers(8, 4, workers).unwrap()),
        ];
        for mut coll in collectives {
            let name = coll.name();
            let cluster = Cluster::new(workers)
                .with_chunk_elems(8)
                .with_backend(backend)
                .with_watchdog(watchdog);
            let mut metrics = ClusterMetrics::new("fault");
            let res = cluster.run(
                3,
                |_| PanicAt {
                    dim: 32,
                    victim: 2,
                    at_step: 1,
                },
                coll.as_mut(),
                &mut metrics,
            );
            let err = res.expect_err("a dead worker must fail the run, not deadlock");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("watchdog") || msg.contains("dropped") || msg.contains("panicked"),
                "{backend:?}/{name}: unexpected error shape: {msg}"
            );
            if backend == Backend::Event {
                // The event watchdog fires at an exact, replayable
                // virtual deadline: the fault is at step 1, so the
                // deadline is step 0's end-of-step clock plus the
                // watchdog. Learn step 0's virtual length from a clean
                // run of an identically constructed collective (same
                // gradients, same chunking, zero compute model).
                let mut twin: Box<dyn ChunkedAllReduce> = if name == "ring" {
                    Box::new(RingAllReduce::new())
                } else {
                    Box::new(FabricAllReduce::for_workers(8, 4, workers).unwrap())
                };
                let mut m2 = ClusterMetrics::new("fault-twin");
                let clean = cluster
                    .run(1, |_| Clean { dim: 32 }, twin.as_mut(), &mut m2)
                    .unwrap();
                let deadline = clean[0].virtual_time_s.unwrap() + watchdog.as_secs_f64();
                assert!(
                    msg.contains("worker 2 panicked"),
                    "{name}: fault must name the victim: {msg}"
                );
                assert!(
                    msg.contains(&format!("virtual deadline t = {deadline:.9} s")),
                    "{name}: deadline must be the exact virtual-time value \
                     {deadline:.9}: {msg}"
                );
            }

            // No poisoned BufferPool/session: the same collective object
            // runs a clean workload to completion immediately afterwards
            // (fresh cluster with the default, generous watchdog).
            let recovery = Cluster::new(workers).with_chunk_elems(8).with_backend(backend);
            let mut metrics = ClusterMetrics::new("recovery");
            let records = recovery
                .run(2, |_| Clean { dim: 32 }, coll.as_mut(), &mut metrics)
                .unwrap_or_else(|e| panic!("{backend:?}/{name}: post-fault run must succeed: {e:#}"));
            assert_eq!(records.len(), 2);
            assert_eq!(metrics.steps(), 2);
        }
    }
}

/// Fault injection, second shape: every worker dies mid-step. On the
/// threaded backend the leader observes the channel disconnections and
/// returns a clean `Err`; on the event backend the same workload trips
/// the watchdog at the exact virtual deadline `step-0 end + watchdog`
/// (first faulting worker in deterministic worker order: worker 0).
#[test]
fn dropped_leader_channels_surface_clean_err() {
    struct DieAt {
        dim: usize,
        at_step: usize,
    }
    impl Workload for DieAt {
        fn grad(&mut self, step: usize, _worker: usize) -> (Vec<f32>, f64) {
            if step == self.at_step {
                panic!("injected mass worker death (test)");
            }
            (vec![0.5; self.dim], 0.0)
        }
        fn apply(&mut self, _step: usize, _worker: usize, _avg: &[f32]) {}
    }

    let workers = 8usize;
    let watchdog = Duration::from_secs(5);
    for backend in [Backend::Threaded, Backend::Event] {
        let collectives: Vec<Box<dyn ChunkedAllReduce>> = vec![
            Box::new(RingAllReduce::new()),
            Box::new(FabricAllReduce::for_workers(8, 4, workers).unwrap()),
        ];
        for mut coll in collectives {
            let name = coll.name();
            let cluster = Cluster::new(workers)
                .with_chunk_elems(16)
                .with_backend(backend)
                .with_watchdog(watchdog);
            let mut metrics = ClusterMetrics::new("mass-fault");
            let res = cluster.run(
                3,
                |_| DieAt { dim: 64, at_step: 1 },
                coll.as_mut(),
                &mut metrics,
            );
            let err = res.expect_err("dropped leader channels must fail the run");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("dropped") || msg.contains("panicked") || msg.contains("watchdog"),
                "{backend:?}/{name}: unexpected error shape: {msg}"
            );
            if backend == Backend::Event {
                // Deterministic in virtual time: worker 0 is the first
                // faulting worker in worker order, every run, and the
                // deadline message carries the step-1 virtual watchdog
                // expiry.
                assert!(
                    msg.contains("worker 0 panicked") && msg.contains("virtual deadline"),
                    "{name}: event fault must be deterministic: {msg}"
                );
                assert!(
                    msg.contains("step 1:"),
                    "{name}: fault must land at step 1: {msg}"
                );
            }
        }
    }
}

/// Fault injection with live error-feedback state (ISSUE 8 satellite):
/// a worker panic mid-step leaves residuals from the completed steps
/// inside the collective. Reusing it must not leak them — `Cluster::run`
/// reinstalls the EF policy, which drops all residual state, so the
/// first post-fault step is bit-identical to a run on a freshly built
/// collective.
#[test]
fn ef_fault_recovery_does_not_leak_residuals() {
    const SEED: u64 = 0xEF5EED;
    const DIM: usize = 20;

    struct EfPanicAt {
        dim: usize,
        victim: usize,
        at_step: usize,
        tx: std::sync::mpsc::Sender<(usize, usize, Vec<u32>)>,
    }
    impl Workload for EfPanicAt {
        fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
            if worker == self.victim && step == self.at_step {
                panic!("injected worker fault with live EF residuals (test)");
            }
            (synth_grad(SEED, step, worker, self.dim), 0.0)
        }
        fn apply(&mut self, step: usize, worker: usize, avg: &[f32]) {
            self.tx
                .send((step, worker, avg.iter().map(|v| v.to_bits()).collect()))
                .ok();
        }
    }

    let workers = 4usize;
    let make = || FabricAllReduce::for_workers(2, 4, workers).unwrap();
    for backend in [Backend::Threaded, Backend::Event] {
        // Steps 0 and 1 complete and charge residual state (2-bit wire:
        // large quantization error, so any leak is numerically visible);
        // the panic lands at step 2.
        let mut survivor = make();
        let fault = Cluster::new(workers)
            .with_chunk_elems(7)
            .with_backend(backend)
            .with_seed(SEED)
            .with_error_feedback(ErrorFeedback::on())
            .with_watchdog(Duration::from_millis(300));
        let (tx, _rx) = std::sync::mpsc::channel();
        let mut metrics = ClusterMetrics::new("ef-fault");
        let err = fault
            .run(
                4,
                move |_| EfPanicAt {
                    dim: DIM,
                    victim: 2,
                    at_step: 2,
                    tx: tx.clone(),
                },
                &mut survivor,
                &mut metrics,
            )
            .expect_err("a dead worker must fail the run");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("watchdog") || msg.contains("dropped") || msg.contains("panicked"),
            "{backend:?}: unexpected fault shape (seed {SEED:#x}): {msg}"
        );

        // Post-fault reuse vs a fresh collective: identical clean run,
        // step for step, bit for bit — stale residuals would shift the
        // very first applied average.
        let clean_run = |coll: &mut dyn ChunkedAllReduce| -> Vec<(usize, usize, Vec<u32>)> {
            let (tx, rx) = std::sync::mpsc::channel();
            let cluster = Cluster::new(workers)
                .with_chunk_elems(7)
                .with_backend(backend)
                .with_seed(SEED)
                .with_error_feedback(ErrorFeedback::on());
            let mut metrics = ClusterMetrics::new("ef-recovery");
            cluster
                .run(
                    2,
                    move |_| EfPanicAt {
                        dim: DIM,
                        victim: usize::MAX,
                        at_step: usize::MAX,
                        tx: tx.clone(),
                    },
                    coll,
                    &mut metrics,
                )
                .unwrap_or_else(|e| {
                    panic!("{backend:?}: post-fault run must succeed (seed {SEED:#x}): {e:#}")
                });
            let mut applied: Vec<_> = rx.try_iter().collect();
            applied.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            applied
        };
        let reused = clean_run(&mut survivor);
        let mut fresh_coll = make();
        let fresh = clean_run(&mut fresh_coll);
        assert_eq!(reused.len(), workers * 2, "{backend:?}: every worker applies");
        assert_eq!(
            reused, fresh,
            "{backend:?}: reused collective must not leak pre-fault EF residuals \
             (replay with seed {SEED:#x})"
        );
    }
}

/// EF on a raw-f32 wire is a contradiction — there is no edge
/// quantization error to compensate — so it must be rejected loudly at
/// run start (on both backends, for both ways of getting an f32 wire),
/// never silently carried as dead residual state.
#[test]
fn ef_on_f32_wire_is_a_validated_error() {
    struct Null;
    impl Workload for Null {
        fn grad(&mut self, _step: usize, _worker: usize) -> (Vec<f32>, f64) {
            (vec![1.0; 8], 0.0)
        }
        fn apply(&mut self, _step: usize, _worker: usize, _avg: &[f32]) {}
    }

    for backend in [Backend::Threaded, Backend::Event] {
        // An f32-native collective…
        let mut ring = RingAllReduce::new();
        let mut metrics = ClusterMetrics::new("ef-f32");
        let err = Cluster::new(2)
            .with_backend(backend)
            .with_error_feedback(ErrorFeedback::on())
            .run(1, |_| Null, &mut ring, &mut metrics)
            .expect_err("EF on the f32 wire must be rejected");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("packed-wire") && msg.contains("ring"),
            "{backend:?}: rejection must name the wire and the collective: {msg}"
        );

        // …and a packed collective forced onto the legacy f32 wire
        // (`pipeline --wire f32`).
        let mut packed = FabricAllReduce::for_workers(4, 4, 2).unwrap();
        let mut metrics = ClusterMetrics::new("ef-forced-f32");
        let err = Cluster::new(2)
            .with_backend(backend)
            .with_f32_wire(true)
            .with_error_feedback(ErrorFeedback::on())
            .run(1, |_| Null, &mut packed, &mut metrics)
            .expect_err("EF with --wire f32 must be rejected");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("--wire f32") && msg.contains("residual"),
            "{backend:?}: forced-f32 rejection must explain the dead residuals: {msg}"
        );
    }
}

/// Zero-length shards with EF enabled: the empty-step protocol must run
/// to completion on both backends without ever allocating residual
/// state, and the collective must stay bit-exact for the sized steps
/// that follow.
#[test]
fn ef_zero_length_shards_allocate_no_residuals() {
    const SEED: u64 = 0xEF5EED;

    struct EmptyThenDense {
        dim: usize,
        tx: std::sync::mpsc::Sender<(usize, usize, Vec<u32>)>,
    }
    impl Workload for EmptyThenDense {
        fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
            // Steps 0–1 are empty (a LocalSGD-style non-sync prefix);
            // step 2 is the first sized round.
            if step < 2 {
                (Vec::new(), 0.0)
            } else {
                (synth_grad(SEED, step, worker, self.dim), 0.0)
            }
        }
        fn apply(&mut self, step: usize, worker: usize, avg: &[f32]) {
            self.tx
                .send((step, worker, avg.iter().map(|v| v.to_bits()).collect()))
                .ok();
        }
    }

    let workers = 4usize;
    for backend in [Backend::Threaded, Backend::Event] {
        let mut coll = FabricAllReduce::for_workers(4, 4, workers).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let cluster = Cluster::new(workers)
            .with_chunk_elems(5)
            .with_backend(backend)
            .with_seed(SEED)
            .with_error_feedback(ErrorFeedback::on());
        let mut metrics = ClusterMetrics::new("ef-empty");
        let records = cluster
            .run(
                3,
                move |_| EmptyThenDense {
                    dim: 13,
                    tx: tx.clone(),
                },
                &mut coll,
                &mut metrics,
            )
            .unwrap_or_else(|e| {
                panic!("{backend:?}: empty EF steps must succeed (seed {SEED:#x}): {e:#}")
            });
        assert_eq!(records.len(), 3);
        let mut applied: Vec<_> = rx.try_iter().collect();
        applied.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        for (step, _, avg) in &applied {
            if *step < 2 {
                assert!(avg.is_empty(), "{backend:?}: empty step must apply nothing");
            }
        }
        // The first sized step after the empty prefix equals a fresh EF
        // stream (no residual state can have formed on empty rounds).
        let shards: Vec<Vec<f32>> = (0..workers).map(|w| synth_grad(SEED, 2, w, 13)).collect();
        let want: Vec<u32> = optinc::quant::ChunkedEfReference::new(4, 5)
            .step(&shards)
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let dense: Vec<_> = applied.iter().filter(|(s, _, _)| *s == 2).collect();
        assert_eq!(dense.len(), workers, "{backend:?}: all workers apply step 2");
        for (_, w, avg) in dense {
            assert_eq!(
                avg, &want,
                "{backend:?} worker {w}: step after empty prefix must match a \
                 fresh EF stream (replay with seed {SEED:#x})"
            );
        }
    }
}

#[test]
fn mesh_noise_ablation_degrades_gracefully() {
    // Non-ideality substrate: phase noise perturbs the realized matrix
    // smoothly (no catastrophic failures at small sigma).
    use optinc::photonics::noise::NoiseModel;
    let mut rng = Pcg32::seeded(5);
    let q = optinc::linalg::random_orthogonal(&mut rng, 16);
    let mesh = MziMesh::program(&q, 1e-8).unwrap();
    let mut last = 0.0;
    for sigma in [1e-4, 1e-3, 1e-2] {
        let dev = NoiseModel::new(sigma, 0.0, 11).matrix_deviation(&mesh);
        assert!(dev > last, "deviation should grow with sigma");
        assert!(dev < 1.0);
        last = dev;
    }
}

#[test]
fn area_model_consistency_rust_vs_scenarios() {
    // The same MZI counts drive Table I and the cascade overhead claim;
    // spot-check the absolute counts so a formula regression is caught
    // by more than ratios.
    use optinc::photonics::area;
    assert_eq!(area::full_matrix_mzis(64, 4), 64 * 65 / 2 + 4 * 3 / 2);
    assert_eq!(area::scenario_mzis(&Scenario::table1(1).unwrap(), false), 106_512);
    assert_eq!(area::scenario_mzis(&Scenario::table1(1).unwrap(), true), 41_664);
}

#[test]
fn json_metrics_cross_language_contract() {
    // Parse a python-written metrics file shape (hand-rolled fixture) and
    // build an error model from it — the Fig. 7a wiring.
    use optinc::optinc::error_model::ErrorModel;
    use optinc::util::json::Json;
    let fixture = r#"{
        "accuracy": 0.9999,
        "errors": {"-1": 30, "1": 60, "-64": 10},
        "area_ratio": 0.393
    }"#;
    let j = Json::parse(fixture).unwrap();
    let em = ErrorModel::from_metrics(&j, 1);
    assert!((em.error_rate - 1e-4).abs() < 1e-9);
    assert_eq!(em.values.len(), 3);
    let mat = Mat::identity(2);
    assert_eq!(mat.rows, 2); // keep linalg linked in this test crate
}

#[test]
fn hardware_aware_training_beats_post_hoc_projection() {
    // The paper's Fig.-accuracy claim in miniature: a network trained
    // with the Σ·U constraint and optical noise *in the loop* must
    // average better than the same architecture trained plainly and
    // projected onto Σ·U after the fact. Property-tested over
    // independently seeded (init, data, noise) training runs.
    use optinc::onn::train::{
        evaluate, project_post_hoc, train_for_scenario, AveragingDataset, HardwareMode,
        TrainConfig,
    };
    use optinc::util::proptest::{forall, Config};

    let sc = Scenario {
        id: 0,
        bits: 8,
        servers: 4,
        layers: vec![4, 16, 16, 4],
        approx_layers: vec![1, 2, 3],
    };
    forall(
        Config {
            cases: 3,
            seed: 0xA11E_6E,
        },
        |rng| rng.next_u64() >> 1,
        |&seed| {
            let base = TrainConfig {
                steps: 400,
                batch: 32,
                seed,
                ..Default::default()
            };
            // Hardware-aware: projected every step, noisy forwards.
            let (aware, report) = train_for_scenario(&sc, &base);
            // Post-hoc baseline: identical budget, unconstrained, then
            // one projection of the scenario's approximated layers.
            let mut plain_cfg = base.clone();
            plain_cfg.hardware = HardwareMode::Unconstrained;
            let (mut plain, _) = train_for_scenario(&sc, &plain_cfg);
            project_post_hoc(&mut plain, &sc.approx_layers);

            let mut held = AveragingDataset::new(&sc, seed ^ 0x0FF5E7);
            let aware_err = evaluate(&aware, &mut held, 1024);
            let mut held = AveragingDataset::new(&sc, seed ^ 0x0FF5E7);
            let post_err = evaluate(&plain, &mut held, 1024);
            if !aware_err.is_finite() || !post_err.is_finite() {
                return Err(format!("non-finite errors: {aware_err} vs {post_err}"));
            }
            if !report.final_loss().is_finite() {
                return Err("aware training diverged".to_string());
            }
            if aware_err < post_err {
                Ok(())
            } else {
                Err(format!(
                    "hardware-aware rel err {aware_err} !< post-hoc {post_err}"
                ))
            }
        },
    );
}

#[test]
fn trained_collective_tracks_exact_oracle() {
    // End-to-end: a natively trained switch inside the full collective
    // (quantize → encode → P → trained ONN → snap → decode → dequantize)
    // must land near the exact-oracle collective on real float shards.
    use optinc::onn::train::TrainConfig;

    let sc = Scenario {
        id: 0,
        bits: 8,
        servers: 4,
        layers: vec![4, 16, 16, 4],
        approx_layers: vec![1, 2, 3],
    };
    let cfg = TrainConfig {
        steps: 300,
        batch: 32,
        seed: 21,
        ..Default::default()
    };
    let mut trained = OptIncAllReduce::trained(sc.clone(), &cfg, 9).unwrap();
    let mut exact = OptIncAllReduce::exact(sc, 9);

    let base = random_shards(4, 512, 33);
    let want = exact_mean(&base);
    let mut got_t = base.clone();
    trained.all_reduce(&mut got_t);
    let mut got_e = base.clone();
    exact.all_reduce(&mut got_e);

    // Workers agree with each other in both modes.
    for s in &got_t[1..] {
        assert_eq!(s, &got_t[0]);
    }
    // The trained network is imperfect but must stay well inside the
    // random-output regime: a random decoder would sit at a mean abs
    // deviation of ~0.67× the block scale; a trained one must do better.
    let mad = |xs: &[f32]| -> f64 {
        xs.iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / xs.len() as f64
    };
    let err_t = mad(&got_t[0]);
    let err_e = mad(&got_e[0]);
    assert!(err_e <= err_t, "oracle can't be worse than a trained net");
    let views: Vec<&[f32]> = base.iter().map(|s| s.as_slice()).collect();
    let scale = optinc::quant::GlobalQuantizer::global_scale(&views) as f64;
    assert!(
        err_t < scale * 0.5,
        "trained collective mad {err_t} vs scale {scale}"
    );
}

#[test]
fn butterfly_trained_collective_tracks_exact_oracle() {
    // Same end-to-end contract as the dense trained switch, with the
    // hardware-aware projection targeting the O(n log n) butterfly set:
    // the factorization is coarser, but the trained collective must stay
    // within the same tolerance the table2 path enforces.
    use optinc::onn::train::{HardwareMode, TrainConfig};

    let sc = Scenario {
        id: 0,
        bits: 8,
        servers: 4,
        layers: vec![4, 16, 16, 4],
        approx_layers: vec![1, 2, 3],
    };
    let cfg = TrainConfig {
        steps: 300,
        batch: 32,
        seed: 21,
        hardware: HardwareMode::aware_butterfly(),
        ..Default::default()
    };
    let mut trained = OptIncAllReduce::trained(sc.clone(), &cfg, 9).unwrap();
    let mut exact = OptIncAllReduce::exact(sc, 9);

    let base = random_shards(4, 512, 33);
    let want = exact_mean(&base);
    let mut got_t = base.clone();
    trained.all_reduce(&mut got_t);
    let mut got_e = base.clone();
    exact.all_reduce(&mut got_e);

    for s in &got_t[1..] {
        assert_eq!(s, &got_t[0]);
    }
    let mad = |xs: &[f32]| -> f64 {
        xs.iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / xs.len() as f64
    };
    let err_t = mad(&got_t[0]);
    let err_e = mad(&got_e[0]);
    assert!(err_e <= err_t, "oracle can't be worse than a trained net");
    let views: Vec<&[f32]> = base.iter().map(|s| s.as_slice()).collect();
    let scale = optinc::quant::GlobalQuantizer::global_scale(&views) as f64;
    assert!(
        err_t < scale * 0.5,
        "butterfly trained collective mad {err_t} vs scale {scale}"
    );
}
