//! Cross-module integration tests: the OptINC datapath end to end
//! (quantize → encode → P → ONN/oracle → snap → decode → dequantize),
//! photonics compile path on real ONN shapes, and the cluster driver
//! with the OptINC collective.

use optinc::cluster::{Cluster, ClusterMetrics, Workload};
use optinc::collectives::engine::ChunkedAllReduce;
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::{exact_mean, AllReduce};
use optinc::config::Scenario;
use optinc::linalg::Mat;
use optinc::optinc::cascade::CascadeMode;
use optinc::photonics::approx::ApproxMatrix;
use optinc::photonics::mesh::MziMesh;
use optinc::quant::GlobalQuantizer;
use optinc::util::rng::Pcg32;

fn random_shards(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.08).collect())
        .collect()
}

#[test]
fn optinc_collective_tracks_ring_within_quantization_floor() {
    // The central functional claim: OptINC's one-traversal average equals
    // the exact ring average up to the B-bit quantization error.
    for (sid, n) in [(1usize, 4usize), (2, 8), (4, 4)] {
        let sc = Scenario::table1(sid).unwrap();
        let base = random_shards(n, 20_000, sid as u64);
        let want = exact_mean(&base);
        let views: Vec<&[f32]> = base.iter().map(|s| s.as_slice()).collect();
        let scale = GlobalQuantizer::global_scale(&views);

        let mut ring_shards = base.clone();
        RingAllReduce::new().all_reduce(&mut ring_shards);
        let mut oi_shards = base.clone();
        let mut oi = OptIncAllReduce::exact(sc, 1);
        oi.all_reduce(&mut oi_shards);

        let q = GlobalQuantizer::new(if sid == 4 { 16 } else { 8 });
        let tol = q.max_abs_error(scale) * 2.0 + 1e-6;
        for (a, b) in oi_shards[0].iter().zip(&want) {
            assert!((a - b).abs() <= tol, "scenario {sid}: {a} vs {b} tol {tol}");
        }
        // Ring is exact.
        for (a, b) in ring_shards[0].iter().zip(&want) {
            assert!((a - b).abs() <= 1e-5);
        }
    }
}

#[test]
fn trained_onn_weights_map_onto_mzi_meshes() {
    // Photonics compile path on a scenario-1-shaped approximated layer:
    // project → per-block Σ·U → program U onto a mesh → propagate and
    // compare against the dense matvec.
    let mut rng = Pcg32::seeded(31);
    let w = optinc::linalg::random_mat(&mut rng, 64, 64);
    let approx = ApproxMatrix::from_dense(&w);
    assert_eq!(approx.blocks.len(), 1);
    let block = &approx.blocks[0];
    let mesh = MziMesh::program(&block.u, 1e-7).unwrap();
    assert_eq!(mesh.mzi_count(), 64 * 63 / 2);

    let x: Vec<f64> = (0..64).map(|i| ((i * 7) % 13) as f64 * 0.25).collect();
    let through_mesh: Vec<f64> = mesh
        .propagate(&x)
        .iter()
        .zip(&block.d)
        .map(|(y, d)| y * d)
        .collect();
    let dense = approx.to_matrix().matvec(&x);
    for (a, b) in through_mesh.iter().zip(&dense) {
        assert!((a - b).abs() < 1e-8, "{a} vs {b}");
    }
}

#[test]
fn cluster_training_converges_with_optinc_collective() {
    // A linear-regression workload trained data-parallel through the
    // exact-oracle OptINC switch must converge like ring does.
    struct LinReg {
        w: Vec<f32>,
        rng: Pcg32,
    }

    impl Workload for LinReg {
        fn grad(&mut self, _step: usize, worker: usize) -> (Vec<f32>, f64) {
            // True weights = [1, -2, 3, 0.5, ...]; squared loss gradient
            // on a fresh random sample.
            let dim = self.w.len();
            let true_w: Vec<f32> = (0..dim).map(|i| ((i % 7) as f32 - 3.0) * 0.5).collect();
            let mut g = vec![0.0f32; dim];
            let mut loss = 0.0f64;
            let batch = 16;
            for _ in 0..batch {
                let x: Vec<f32> = (0..dim).map(|_| self.rng.normal() as f32).collect();
                let y: f32 = x.iter().zip(&true_w).map(|(a, b)| a * b).sum();
                let pred: f32 = x.iter().zip(&self.w).map(|(a, b)| a * b).sum();
                let err = pred - y;
                loss += (err * err) as f64;
                for (gi, xi) in g.iter_mut().zip(&x) {
                    *gi += 2.0 * err * xi / batch as f32;
                }
            }
            let _ = worker;
            (g, loss / batch as f64)
        }

        fn apply(&mut self, _step: usize, _worker: usize, avg: &[f32]) {
            for (w, g) in self.w.iter_mut().zip(avg) {
                *w -= 0.05 * g;
            }
        }
    }

    let run = |coll: &mut dyn ChunkedAllReduce| -> (f64, f64) {
        // Stream in small chunks so the pipelined path is exercised on a
        // real convergence run (dim 32 → 8 chunks of 4).
        let cluster = Cluster::new(4).with_chunk_elems(4);
        let mut metrics = ClusterMetrics::new("linreg");
        let records = cluster
            .run(
                60,
                |w| LinReg {
                    w: vec![0.0; 32],
                    rng: Pcg32::seeded(100 + w as u64),
                },
                coll,
                &mut metrics,
            )
            .unwrap();
        (records[0].mean_loss, records.last().unwrap().mean_loss)
    };

    let (ring_first, ring_last) = run(&mut RingAllReduce::new());
    let sc = Scenario::table1(4).unwrap(); // 16-bit for a tight floor
    let (oi_first, oi_last) = run(&mut OptIncAllReduce::exact(sc, 3));

    assert!(ring_last < ring_first * 0.05, "ring: {ring_first} -> {ring_last}");
    assert!(oi_last < oi_first * 0.05, "optinc: {oi_first} -> {oi_last}");
    // Final quality comparable (within 5x — both near the noise floor).
    assert!(oi_last < ring_last * 5.0 + 1e-3);
}

#[test]
fn cascade_collective_equals_flat_switch_on_cluster_gradients() {
    let base = random_shards(16, 5_000, 77);
    let sc4 = Scenario::table1(1).unwrap();
    let sc16 = Scenario::table1(3).unwrap();

    let mut a = base.clone();
    HierarchicalOptInc::new(sc4, CascadeMode::Remainder).all_reduce(&mut a);
    let mut b = base.clone();
    OptIncAllReduce::exact(sc16, 1).all_reduce(&mut b);
    assert_eq!(a[0], b[0]);
}

#[test]
fn mesh_noise_ablation_degrades_gracefully() {
    // Non-ideality substrate: phase noise perturbs the realized matrix
    // smoothly (no catastrophic failures at small sigma).
    use optinc::photonics::noise::NoiseModel;
    let mut rng = Pcg32::seeded(5);
    let q = optinc::linalg::random_orthogonal(&mut rng, 16);
    let mesh = MziMesh::program(&q, 1e-8).unwrap();
    let mut last = 0.0;
    for sigma in [1e-4, 1e-3, 1e-2] {
        let dev = NoiseModel::new(sigma, 0.0, 11).matrix_deviation(&mesh);
        assert!(dev > last, "deviation should grow with sigma");
        assert!(dev < 1.0);
        last = dev;
    }
}

#[test]
fn area_model_consistency_rust_vs_scenarios() {
    // The same MZI counts drive Table I and the cascade overhead claim;
    // spot-check the absolute counts so a formula regression is caught
    // by more than ratios.
    use optinc::photonics::area;
    assert_eq!(area::full_matrix_mzis(64, 4), 64 * 65 / 2 + 4 * 3 / 2);
    assert_eq!(area::scenario_mzis(&Scenario::table1(1).unwrap(), false), 106_512);
    assert_eq!(area::scenario_mzis(&Scenario::table1(1).unwrap(), true), 41_664);
}

#[test]
fn json_metrics_cross_language_contract() {
    // Parse a python-written metrics file shape (hand-rolled fixture) and
    // build an error model from it — the Fig. 7a wiring.
    use optinc::optinc::error_model::ErrorModel;
    use optinc::util::json::Json;
    let fixture = r#"{
        "accuracy": 0.9999,
        "errors": {"-1": 30, "1": 60, "-64": 10},
        "area_ratio": 0.393
    }"#;
    let j = Json::parse(fixture).unwrap();
    let em = ErrorModel::from_metrics(&j, 1);
    assert!((em.error_rate - 1e-4).abs() < 1e-9);
    assert_eq!(em.values.len(), 3);
    let mat = Mat::identity(2);
    assert_eq!(mat.rows, 2); // keep linalg linked in this test crate
}
