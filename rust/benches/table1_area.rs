//! Bench/regeneration target for Table I: area ratios per scenario (the
//! analytic MZI model) plus the cost of programming real meshes for the
//! scenario-1 layer sizes.
//!
//! Run: `cargo bench --bench table1_area` (OPTINC_BENCH_QUICK=1 for CI).

use optinc::config::Scenario;
use optinc::linalg::random_orthogonal;
use optinc::photonics::{area, mesh::MziMesh};
use optinc::util::bench::{black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn main() {
    let mut suite = BenchSuite::new("table1_area");

    // The table itself (analytic, recorded as scalars for provenance).
    for id in 1..=4 {
        let sc = Scenario::table1(id).unwrap();
        suite.record_scalar(
            &format!("scenario{id}/area_ratio"),
            area::area_ratio(&sc),
            "ratio",
        );
        suite.record_scalar(
            &format!("scenario{id}/mzis_approx"),
            area::scenario_mzis(&sc, true) as f64,
            "MZIs",
        );
    }
    let paper = [0.393, 0.409, 0.404, 0.493];
    for (id, want) in (1..=4).zip(paper) {
        let got = area::area_ratio(&Scenario::table1(id).unwrap());
        assert!(
            (got - want).abs() < 0.002,
            "scenario {id} diverged from paper: {got} vs {want}"
        );
    }

    // Mesh-programming cost (the offline compile path) per unitary size.
    for n in [64usize, 128, 256] {
        let mut rng = Pcg32::seeded(n as u64);
        let q = random_orthogonal(&mut rng, n);
        suite.bench(&format!("program_mesh/{n}x{n}"), || {
            black_box(MziMesh::program(&q, 1e-7).unwrap());
        });
    }

    // Signal propagation through a programmed mesh (the optical forward).
    let mut rng = Pcg32::seeded(9);
    let q = random_orthogonal(&mut rng, 128);
    let mesh = MziMesh::program(&q, 1e-7).unwrap();
    let x: Vec<f64> = (0..128).map(|i| (i as f64).sin()).collect();
    suite.bench_throughput("propagate/128", 128.0, "elem", || {
        black_box(mesh.propagate(&x));
    });

    suite.finish();
}
