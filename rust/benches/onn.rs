//! ONN-forward mesh bench: dense Clements array vs butterfly
//! factorization across switch radices n ∈ {16, 64, 256, 1024}.
//!
//! Measures propagate throughput (the per-symbol optical matmul cost the
//! switch pays on every frame), records the analytic MZI counts
//! (`n(n−1)/2` vs `(n/2)·log₂n`), the butterfly programming residuals
//! (≈0 for realizable targets, O(1) for arbitrary orthogonal ones), the
//! Table I area ratios under both mesh kinds, and the equal-area radix a
//! butterfly budget buys. `-- --json` writes the `BENCH_onn.json`
//! artifact CI uploads.
//!
//! Dense meshes are built directly from random angles in the interleaved
//! column pattern — programming a 1024×1024 target through the O(n³)
//! decomposition would dominate the bench without changing the
//! propagate cost being measured.

use optinc::config::Scenario;
use optinc::linalg::random_orthogonal;
use optinc::photonics::area::{
    area_ratio_kind, butterfly_unitary_mzis, equal_area_radix, unitary_mzis,
};
use optinc::photonics::butterfly::{ButterflyMesh, FitConfig};
use optinc::photonics::mesh::MeshKind;
use optinc::photonics::mesh::MziMesh;
use optinc::photonics::mzi::Mzi;
use optinc::util::bench::{arg_flag, black_box, BenchSuite};
use optinc::util::rng::Pcg32;

/// A random-angle dense mesh in the interleaved column pattern: `n`
/// columns alternating `n/2` and `n/2 − 1` MZIs (even `n`) — exactly
/// `n(n−1)/2` rotations, the same structure `MziMesh::program` emits.
fn random_dense_mesh(n: usize, seed: u64) -> MziMesh {
    let mut rng = Pcg32::seeded(seed);
    let mut mzis = Vec::with_capacity(n * (n - 1) / 2);
    for col in 0..n {
        let mut port = col % 2;
        while port + 1 < n {
            mzis.push(Mzi::new(
                port,
                rng.uniform(-std::f64::consts::PI, std::f64::consts::PI),
            ));
            port += 2;
        }
    }
    assert_eq!(mzis.len(), n * (n - 1) / 2);
    let signs = (0..n)
        .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
        .collect();
    MziMesh {
        size: n,
        mzis,
        signs,
    }
}

fn main() {
    let json_mode = arg_flag("--json");
    let mut suite = if json_mode {
        BenchSuite::quick("onn")
    } else {
        BenchSuite::new("onn")
    };

    // Propagate throughput + device counts per radix.
    for &n in &[16usize, 64, 256, 1024] {
        suite.record_scalar(&format!("mzis/dense/{n}"), unitary_mzis(n) as f64, "mzi");
        suite.record_scalar(
            &format!("mzis/butterfly/{n}"),
            butterfly_unitary_mzis(n) as f64,
            "mzi",
        );

        let dense = random_dense_mesh(n, 0xD0 + n as u64);
        let bf = ButterflyMesh::random(n, 0xBF + n as u64);
        let mut rng = Pcg32::seeded(n as u64);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        suite.bench_throughput(&format!("propagate/dense/{n}"), 1.0, "prop", || {
            black_box(dense.propagate(&x));
        });
        suite.bench_throughput(&format!("propagate/butterfly/{n}"), 1.0, "prop", || {
            black_box(bf.propagate(&x));
        });
    }

    // Programming residuals: exact on the butterfly-realizable set,
    // honest O(1) on arbitrary orthogonal targets (the set is smaller).
    let realizable = ButterflyMesh::random(16, 7).to_matrix();
    let (_, res) = ButterflyMesh::fit(&realizable, &FitConfig::default());
    suite.record_scalar("fit_residual/realizable/16", res, "rel");
    for &n in &[16usize, 64] {
        let mut rng = Pcg32::seeded(0x0A + n as u64);
        let q = random_orthogonal(&mut rng, n);
        let (_, res) = ButterflyMesh::fit(&q, &FitConfig::default());
        suite.record_scalar(&format!("fit_residual/orthogonal/{n}"), res, "rel");
    }

    // Table I area ratios under both mesh kinds (shared dense full-SVD
    // denominator) + the equal-area radix a 256-port dense budget buys.
    for id in 1..=4 {
        let sc = Scenario::table1(id).unwrap();
        suite.record_scalar(
            &format!("area_ratio/dense/s{id}"),
            area_ratio_kind(&sc, MeshKind::Dense),
            "ratio",
        );
        suite.record_scalar(
            &format!("area_ratio/butterfly/s{id}"),
            area_ratio_kind(&sc, MeshKind::Butterfly),
            "ratio",
        );
    }
    suite.record_scalar("equal_area_radix/256", equal_area_radix(256) as f64, "port");

    if json_mode {
        suite.finish_named("BENCH_onn");
    } else {
        suite.finish();
    }
}
