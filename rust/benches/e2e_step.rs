//! End-to-end step latency through the PJRT path (needs `make artifacts`):
//! grad execution + collective + Adam update for the LM workload, the
//! whole-stack number the perf pass tracks. Skips gracefully when the
//! artifacts have not been built.

use std::sync::Arc;

use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::config::Scenario;
use optinc::runtime::Runtime;
use optinc::train::{DpTrainer, WorkloadKind};
use optinc::util::bench::BenchSuite;

fn main() {
    let rt = match Runtime::new() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("e2e_step: PJRT unavailable ({e}); skipping");
            return;
        }
    };
    if !rt.artifact_exists("lm_adam") {
        println!("e2e_step: artifacts missing (run `make artifacts`); skipping");
        return;
    }
    let mut suite = BenchSuite::new("e2e_step");

    // One full DP step (4 workers) under each collective.
    let mut ring = RingAllReduce::new();
    let mut trainer = DpTrainer::new(rt.clone(), WorkloadKind::Lm).unwrap();
    let params = trainer.param_count() as f64;
    suite.bench_throughput("lm_step/ring/4w", params, "param", || {
        trainer.run(4, 1, &mut ring, 5, 0).unwrap();
    });

    let mut coll = OptIncAllReduce::exact(Scenario::table1(4).unwrap(), 5);
    let mut trainer = DpTrainer::new(rt.clone(), WorkloadKind::Lm).unwrap();
    suite.bench_throughput("lm_step/optinc/4w", params, "param", || {
        trainer.run(4, 1, &mut coll, 5, 0).unwrap();
    });

    // The PJRT switch artifact itself, if lowered (scenario 1, b4096).
    if rt.artifact_exists("switch_onn_s1_b4096") {
        let exe = rt.load("switch_onn_s1_b4096").unwrap();
        let plane = vec![1.0f32; 4096 * 4 * 4];
        let lit = optinc::runtime::lit_f32(&plane, &[4096, 4, 4]).unwrap();
        suite.bench_throughput("pjrt_switch/s1/b4096", 4096.0, "word", || {
            exe.run(std::slice::from_ref(&lit)).unwrap();
        });
    }

    suite.finish();
}
