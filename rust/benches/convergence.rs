//! Convergence bench: the bits × error-feedback × workload sweep on the
//! event backend, timed end to end, with the convergence scalars the
//! sweep exists to measure recorded per row — relative cumulative error
//! (dense), synced-model gap and final loss (LocalSGD), and the virtual
//! step time (the straggler rows stretch it without touching the
//! arithmetic). `-- --json` writes the `BENCH_convergence.json`
//! trajectory artifact.

use optinc::experiments::convergence::{run as run_sweep, SweepConfig};
use optinc::util::bench::{arg_flag, black_box, BenchSuite};

fn main() {
    let json_mode = arg_flag("--json");
    let mut suite = if json_mode {
        BenchSuite::quick("convergence-event")
    } else {
        BenchSuite::new("convergence")
    };

    let cfg = SweepConfig::default();

    // Wall-clock: one full dense EF-on run per wire width (the sweep's
    // hot cell — every step quantizes, feeds back, and streams).
    for &bits in &cfg.bits {
        let one = SweepConfig {
            bits: vec![bits],
            ..cfg.clone()
        };
        suite.bench_throughput(
            &format!(
                "sweep_cell/{}x{}xT{}/b{bits}",
                one.workers, one.dim, one.steps
            ),
            (one.workers * one.dim * one.steps) as f64,
            "elem",
            || {
                black_box(run_sweep(&one).unwrap());
            },
        );
    }

    // The convergence scalars themselves, from the canonical config —
    // what EXPERIMENTS.md §Convergence quotes, tracked as a trajectory
    // in BENCH_convergence.json.
    let rows = run_sweep(&cfg).unwrap();
    for r in &rows {
        let ef = if r.ef { "on" } else { "off" };
        suite.record_scalar(
            &format!("rel_err/{}/b{}/ef_{ef}", r.workload, r.bits),
            r.metric,
            "rel",
        );
        if r.workload == "localsgd" {
            suite.record_scalar(
                &format!("final_loss/{}/b{}/ef_{ef}", r.workload, r.bits),
                r.final_loss,
                "loss",
            );
        }
        suite.record_scalar(
            &format!("virtual_step/{}/b{}/ef_{ef}", r.workload, r.bits),
            r.mean_virtual_step_s * 1e6,
            "us",
        );
    }

    if json_mode {
        suite.finish_named("BENCH_convergence");
    } else {
        suite.finish();
    }
}
