//! Bench/regeneration target for Fig. 6: normalized communication data
//! for ring all-reduce vs OptINC at N ∈ {4, 8, 16} — measured from the
//! simulator's byte counters and asserted against the closed forms —
//! plus wall-clock throughput of the collectives themselves.

use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::AllReduce;
use optinc::config::Scenario;
use optinc::experiments::fig6;
use optinc::util::bench::{black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn main() {
    let mut suite = BenchSuite::new("fig6_comm");

    // The figure's data series (measured byte counters).
    for row in fig6::rows(100_000).unwrap() {
        suite.record_scalar(
            &format!("N{}/ring_normalized", row.servers),
            row.ring_measured,
            "x payload",
        );
        suite.record_scalar(
            &format!("N{}/optinc_normalized", row.servers),
            row.optinc_measured,
            "x payload",
        );
        suite.record_scalar(
            &format!("N{}/two_tree_normalized", row.servers),
            row.two_tree_measured,
            "x payload",
        );
        assert!((row.ring_measured - row.ring_analytic).abs() < 0.01);
        assert!((row.optinc_measured - 1.0).abs() < 0.01);
    }

    // Collective wall-clock (simulator throughput, elements/s).
    let elements = 250_000usize;
    for (id, n) in [(1usize, 4usize), (2, 8), (3, 16)] {
        let mut rng = Pcg32::seeded(n as u64);
        let shards: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..elements).map(|_| rng.normal() as f32 * 0.1).collect())
            .collect();

        let mut work = shards.clone();
        suite.bench_throughput(&format!("ring/N{n}/{elements}"), elements as f64, "elem", || {
            work.clone_from(&shards);
            black_box(RingAllReduce::new().all_reduce(&mut work));
        });

        let sc = Scenario::table1(id).unwrap();
        let mut coll = OptIncAllReduce::exact(sc, 3);
        let mut work = shards.clone();
        suite.bench_throughput(
            &format!("optinc_oracle/N{n}/{elements}"),
            elements as f64,
            "elem",
            || {
                work.clone_from(&shards);
                black_box(coll.all_reduce(&mut work));
            },
        );
    }

    suite.finish();
}
