//! Collective micro-benchmarks: ring vs OptINC vs two-tree vs cascade at
//! matched payloads, scaling in element count, and the chunked streaming
//! engine vs the monolithic one-shot path — both wall-clock (the
//! chunking overhead must stay negligible) and modeled step time (the
//! overlap win, measured rather than asserted). The L3 hot loop the perf
//! pass optimizes (EXPERIMENTS.md §Perf, §Pipelined engine).

use optinc::collectives::engine::{ChunkedDriver, ReducePlan};
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::two_tree::TwoTreeAllReduce;
use optinc::collectives::wire::{
    pack_quantized_into, pack_words_into, packed_len, reference, unpack_dequantize_into,
    unpack_words_into,
};
use optinc::collectives::AllReduce;
use optinc::config::{HardwareModel, Scenario};
use optinc::optinc::cascade::CascadeMode;
use optinc::optinc::switch::OptIncSwitch;
use optinc::quant::GlobalQuantizer;
use optinc::util::bench::{arg_flag, black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn shards(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect()
}

/// The packed-wire perf section: codec throughput, packed-vs-f32 wire
/// volume and end-to-end driver throughput, and the pool's steady-state
/// allocation scalars on a ragged chunk stream. Runs inside the full
/// suite and as the `--json` quick artifact (`BENCH_wire.json`).
fn wire_section(suite: &mut BenchSuite) {
    let len = 1_000_000usize;
    let mut rng = Pcg32::seeded(0x11AE);
    let q = GlobalQuantizer::new(8);
    let gs: Vec<f32> = (0..len).map(|_| (rng.normal() * 0.1) as f32).collect();
    let scale = GlobalQuantizer::global_scale(&[&gs]);
    let words: Vec<u32> = gs.iter().map(|&g| q.quantize(g, scale)).collect();

    // Codec throughput per bit width: what the edge pays to put packed
    // words on the wire (and take them back off). 8/16/32 take the
    // byte-aligned lane fast paths; 4 takes the generic u64-accumulator
    // path.
    let mut packed = Vec::with_capacity(len);
    for bits in [4u32, 8, 16, 32] {
        let wmask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let in_range: Vec<u32> = words.iter().map(|&w| w & wmask).collect();
        suite.bench_throughput(&format!("wire/pack_{bits}bit/1M"), len as f64, "word", || {
            pack_words_into(&in_range, bits, &mut packed);
            black_box(packed.len());
        });
        let mut unpacked = vec![0u32; len];
        suite.bench_throughput(
            &format!("wire/unpack_{bits}bit/1M"),
            len as f64,
            "word",
            || {
                unpack_words_into(&packed, bits, &mut unpacked);
                black_box(unpacked.len());
            },
        );
    }
    // Re-pin `packed` to the 8-bit payload for the volume scalars below.
    pack_words_into(&words, 8, &mut packed);

    // The retained per-element scalar codec — the pre-vectorization
    // baseline the lane codec is measured against (and the property
    // tests' oracle). The measured ratio is the real-machine companion
    // to the analytic `codec_model/*` scalars in BENCH_wire.json.
    let mut ref_packed = Vec::with_capacity(len);
    let r = suite
        .bench_throughput("wire/pack_8bit_scalar_ref/1M", len as f64, "word", || {
            reference::pack_scalar(&words, 8, &mut ref_packed);
            black_box(ref_packed.len());
        })
        .mean_s();
    let f = suite
        .bench_throughput("wire/pack_8bit_vector/1M", len as f64, "word", || {
            pack_words_into(&words, 8, &mut ref_packed);
            black_box(ref_packed.len());
        })
        .mean_s();
    suite.record_scalar("wire/codec_speedup/pack8_measured", r / f, "x");

    // Fused quantize+pack / unpack+dequantize — the one-pass edge
    // kernels the cluster backends call per chunk.
    let mut fused = Vec::with_capacity(len);
    suite.bench_throughput("wire/fused_quantize_pack_8bit/1M", len as f64, "elem", || {
        pack_quantized_into(&gs, &q, scale, &mut fused);
        black_box(fused.len());
    });
    let mut floats = vec![0.0f32; len];
    suite.bench_throughput(
        "wire/fused_unpack_dequantize_8bit/1M",
        len as f64,
        "elem",
        || {
            unpack_dequantize_into(&fused, &q, scale, &mut floats);
            black_box(floats.len());
        },
    );

    // Parallel leader reduce: the 16-port exact switch's word-domain
    // shard accumulation at 1/2/4/8 range-splitting threads. Speedups
    // are measured on whatever host runs the bench; the committed
    // artifact's modeled curve is the Amdahl companion.
    let rlen = 262_144usize;
    let rshards: Vec<Vec<u32>> = (0..16)
        .map(|s| {
            let mut rng = Pcg32::seeded(0x5CA1E + s as u64);
            (0..rlen).map(|_| (rng.normal().abs() * 40.0) as u32 & 0xFF).collect()
        })
        .collect();
    let views: Vec<&[u32]> = rshards.iter().map(|v| v.as_slice()).collect();
    let mut avg = Vec::with_capacity(rlen);
    let mut t1 = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let mut sw = OptIncSwitch::exact(Scenario::table1(3).unwrap());
        sw.set_reduce_plan(ReducePlan::with_threads(threads).with_threshold(1));
        let t = suite
            .bench_throughput(
                &format!("reduce/switch16_words/t{threads}/256k"),
                rlen as f64,
                "elem",
                || {
                    sw.average_words_into(&views, &mut avg);
                    black_box(avg.len());
                },
            )
            .mean_s();
        if threads == 1 {
            t1 = t;
        } else {
            suite.record_scalar(
                &format!("reduce/speedup_measured/t{threads}"),
                t1 / t,
                "x",
            );
        }
    }
    // The f32 wire's per-chunk work for the same payload (a memcpy).
    let mut f32_buf = vec![0.0f32; len];
    suite.bench_throughput("wire/f32_copy/1M", len as f64, "elem", || {
        f32_buf.copy_from_slice(&gs);
        black_box(f32_buf.len());
    });

    // Wire volume scalars: the 4x the packed transport closes at 8 bits.
    let packed_bytes = packed_len(len, 8) as f64;
    suite.record_scalar("wire/bytes_per_server/packed8", packed_bytes, "B");
    suite.record_scalar("wire/bytes_per_server/f32", (len * 4) as f64, "B");
    suite.record_scalar("wire/reduction", (len * 4) as f64 / packed_bytes, "x");

    // End-to-end packed pipeline (the float adapter runs the word-domain
    // path) vs the f32 ring baseline at a matched payload.
    let n = 4usize;
    let elen = 100_000usize;
    let base = shards(n, elen, 0xE2E);
    let mut work = base.clone();
    let sc = Scenario::table1(1).unwrap();
    let mut driver = ChunkedDriver::new(elen / 16);
    let mut coll = OptIncAllReduce::exact(sc, 1);
    suite.bench_throughput("wire/e2e/optinc_packed/4x100k", elen as f64, "elem", || {
        work.clone_from(&base);
        black_box(driver.all_reduce(&mut coll, &mut work));
    });
    let mut ring = RingAllReduce::new();
    suite.bench_throughput("wire/e2e/ring_f32/4x100k", elen as f64, "elem", || {
        work.clone_from(&base);
        black_box(driver.all_reduce(&mut ring, &mut work));
    });

    // Pool steady state on a ragged stream (chunk grain does not divide
    // the payload, so every step ends with a short chunk): after warmup
    // the driver must stop allocating.
    let ragged = shards(n, 10_000, 0xBAD);
    let mut work = ragged.clone();
    let mut driver = ChunkedDriver::new(1 + 10_000 / 7);
    let mut coll = OptIncAllReduce::exact(Scenario::table1(1).unwrap(), 1);
    for _ in 0..3 {
        work.clone_from(&ragged);
        driver.all_reduce(&mut coll, &mut work);
    }
    let warm = driver.pool_allocations();
    for _ in 0..10 {
        work.clone_from(&ragged);
        driver.all_reduce(&mut coll, &mut work);
    }
    let steady = driver.pool_allocations() - warm;
    suite.record_scalar("wire/pool_allocations/warmup", warm as f64, "allocs");
    suite.record_scalar("wire/pool_allocations/steady10", steady as f64, "allocs");
    assert_eq!(steady, 0, "ragged chunk stream must not allocate once warm");
}

fn main() {
    // Artifact mode: `cargo bench --bench allreduce -- --json` runs only
    // the wire section at the quick config and pins the output file for
    // the CI perf-trajectory upload.
    if arg_flag("--json") {
        let mut suite = BenchSuite::quick("wire");
        wire_section(&mut suite);
        suite.finish_named("BENCH_wire");
        return;
    }
    let mut suite = BenchSuite::new("allreduce");
    let sc = Scenario::table1(1).unwrap();

    for len in [10_000usize, 100_000, 1_000_000] {
        let base = shards(4, len, len as u64);
        let mut work = base.clone();

        suite.bench_throughput(&format!("ring/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(RingAllReduce::new().all_reduce(&mut work));
        });

        let mut coll = OptIncAllReduce::exact(sc.clone(), 1);
        suite.bench_throughput(&format!("optinc/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(coll.all_reduce(&mut work));
        });

        suite.bench_throughput(&format!("two_tree/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(TwoTreeAllReduce::new().all_reduce(&mut work));
        });
    }

    // Chunked streaming vs monolithic, sweeping the chunk grain: the
    // wall-clock cost of chunking (copies + per-chunk setup) against the
    // monolithic baseline at the same 1M-element payload.
    let len = 1_000_000usize;
    let base = shards(4, len, 77);
    let mut work = base.clone();
    for chunk in [len, 262_144usize, 65_536, 16_384] {
        let mut driver = ChunkedDriver::new(chunk);
        let mut ring = RingAllReduce::new();
        suite.bench_throughput(
            &format!("ring_chunked/c{chunk}/4x{len}"),
            len as f64,
            "elem",
            || {
                work.clone_from(&base);
                black_box(driver.all_reduce(&mut ring, &mut work));
            },
        );
        let mut coll = OptIncAllReduce::exact(sc.clone(), 1);
        suite.bench_throughput(
            &format!("optinc_chunked/c{chunk}/4x{len}"),
            len as f64,
            "elem",
            || {
                work.clone_from(&base);
                black_box(driver.all_reduce(&mut coll, &mut work));
            },
        );
    }

    // Modeled step time: the pipelined schedule vs the monolithic one,
    // per worker count — the overlap win the engine exists for. The
    // speedup scalar must exceed 1.0 for every N ≥ 4.
    let hw = HardwareModel::default();
    for (sid, workers) in [(1usize, 4usize), (2, 8), (3, 16)] {
        let len = 100_000usize;
        let base = shards(workers, len, 90 + workers as u64);
        let scn = Scenario::table1(sid).unwrap();

        let mut coll = OptIncAllReduce::exact(scn, 5);
        let mut mono = base.clone();
        let mono_stats = coll.all_reduce(&mut mono);
        let mut piped = base.clone();
        let mut driver = ChunkedDriver::new(len / 16);
        let piped_stats = driver.all_reduce(&mut coll, &mut piped);

        let t_mono = mono_stats.modeled_step_time_s(&hw);
        let t_piped = piped_stats.modeled_step_time_s(&hw);
        suite.record_scalar(
            &format!("modeled_step/optinc/N{workers}/monolithic"),
            t_mono * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("modeled_step/optinc/N{workers}/pipelined"),
            t_piped * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("modeled_step/optinc/N{workers}/speedup"),
            t_mono / t_piped,
            "x",
        );
        assert!(
            t_piped < t_mono,
            "N={workers}: pipelined {t_piped} must beat monolithic {t_mono}"
        );
    }

    // Cascade at 16 workers.
    let base = shards(16, 100_000, 99);
    let mut work = base.clone();
    let sc = Scenario::table1(1).unwrap();
    let mut casc = HierarchicalOptInc::new(sc, CascadeMode::Remainder);
    suite.bench_throughput("cascade/16x100000", 100_000.0, "elem", || {
        work.clone_from(&base);
        black_box(casc.all_reduce(&mut work));
    });

    wire_section(&mut suite);

    suite.finish();
}
