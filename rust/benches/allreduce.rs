//! Collective micro-benchmarks: ring vs OptINC vs two-tree vs cascade at
//! matched payloads, plus scaling in element count — the L3 hot loop the
//! perf pass optimizes (EXPERIMENTS.md §Perf).

use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::two_tree::TwoTreeAllReduce;
use optinc::collectives::AllReduce;
use optinc::config::Scenario;
use optinc::optinc::cascade::CascadeMode;
use optinc::util::bench::{black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn shards(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect()
}

fn main() {
    let mut suite = BenchSuite::new("allreduce");
    let sc = Scenario::table1(1).unwrap();

    for len in [10_000usize, 100_000, 1_000_000] {
        let base = shards(4, len, len as u64);
        let mut work = base.clone();

        suite.bench_throughput(&format!("ring/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(RingAllReduce.all_reduce(&mut work));
        });

        let mut coll = OptIncAllReduce::exact(sc.clone(), 1);
        suite.bench_throughput(&format!("optinc/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(coll.all_reduce(&mut work));
        });

        suite.bench_throughput(&format!("two_tree/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(TwoTreeAllReduce.all_reduce(&mut work));
        });
    }

    // Cascade at 16 workers.
    let base = shards(16, 100_000, 99);
    let mut work = base.clone();
    let mut casc = HierarchicalOptInc::new(sc, CascadeMode::Remainder);
    suite.bench_throughput("cascade/16x100000", 100_000.0, "elem", || {
        work.clone_from(&base);
        black_box(casc.all_reduce(&mut work));
    });

    suite.finish();
}
