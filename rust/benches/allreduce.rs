//! Collective micro-benchmarks: ring vs OptINC vs two-tree vs cascade at
//! matched payloads, scaling in element count, and the chunked streaming
//! engine vs the monolithic one-shot path — both wall-clock (the
//! chunking overhead must stay negligible) and modeled step time (the
//! overlap win, measured rather than asserted). The L3 hot loop the perf
//! pass optimizes (EXPERIMENTS.md §Perf, §Pipelined engine).

use optinc::collectives::engine::ChunkedDriver;
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::two_tree::TwoTreeAllReduce;
use optinc::collectives::AllReduce;
use optinc::config::{HardwareModel, Scenario};
use optinc::optinc::cascade::CascadeMode;
use optinc::util::bench::{black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn shards(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect()
}

fn main() {
    let mut suite = BenchSuite::new("allreduce");
    let sc = Scenario::table1(1).unwrap();

    for len in [10_000usize, 100_000, 1_000_000] {
        let base = shards(4, len, len as u64);
        let mut work = base.clone();

        suite.bench_throughput(&format!("ring/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(RingAllReduce::new().all_reduce(&mut work));
        });

        let mut coll = OptIncAllReduce::exact(sc.clone(), 1);
        suite.bench_throughput(&format!("optinc/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(coll.all_reduce(&mut work));
        });

        suite.bench_throughput(&format!("two_tree/4x{len}"), len as f64, "elem", || {
            work.clone_from(&base);
            black_box(TwoTreeAllReduce::new().all_reduce(&mut work));
        });
    }

    // Chunked streaming vs monolithic, sweeping the chunk grain: the
    // wall-clock cost of chunking (copies + per-chunk setup) against the
    // monolithic baseline at the same 1M-element payload.
    let len = 1_000_000usize;
    let base = shards(4, len, 77);
    let mut work = base.clone();
    for chunk in [len, 262_144usize, 65_536, 16_384] {
        let mut driver = ChunkedDriver::new(chunk);
        let mut ring = RingAllReduce::new();
        suite.bench_throughput(
            &format!("ring_chunked/c{chunk}/4x{len}"),
            len as f64,
            "elem",
            || {
                work.clone_from(&base);
                black_box(driver.all_reduce(&mut ring, &mut work));
            },
        );
        let mut coll = OptIncAllReduce::exact(sc.clone(), 1);
        suite.bench_throughput(
            &format!("optinc_chunked/c{chunk}/4x{len}"),
            len as f64,
            "elem",
            || {
                work.clone_from(&base);
                black_box(driver.all_reduce(&mut coll, &mut work));
            },
        );
    }

    // Modeled step time: the pipelined schedule vs the monolithic one,
    // per worker count — the overlap win the engine exists for. The
    // speedup scalar must exceed 1.0 for every N ≥ 4.
    let hw = HardwareModel::default();
    for (sid, workers) in [(1usize, 4usize), (2, 8), (3, 16)] {
        let len = 100_000usize;
        let base = shards(workers, len, 90 + workers as u64);
        let scn = Scenario::table1(sid).unwrap();

        let mut coll = OptIncAllReduce::exact(scn, 5);
        let mut mono = base.clone();
        let mono_stats = coll.all_reduce(&mut mono);
        let mut piped = base.clone();
        let mut driver = ChunkedDriver::new(len / 16);
        let piped_stats = driver.all_reduce(&mut coll, &mut piped);

        let t_mono = mono_stats.modeled_step_time_s(&hw);
        let t_piped = piped_stats.modeled_step_time_s(&hw);
        suite.record_scalar(
            &format!("modeled_step/optinc/N{workers}/monolithic"),
            t_mono * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("modeled_step/optinc/N{workers}/pipelined"),
            t_piped * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("modeled_step/optinc/N{workers}/speedup"),
            t_mono / t_piped,
            "x",
        );
        assert!(
            t_piped < t_mono,
            "N={workers}: pipelined {t_piped} must beat monolithic {t_mono}"
        );
    }

    // Cascade at 16 workers.
    let base = shards(16, 100_000, 99);
    let mut work = base.clone();
    let sc = Scenario::table1(1).unwrap();
    let mut casc = HierarchicalOptInc::new(sc, CascadeMode::Remainder);
    suite.bench_throughput("cascade/16x100000", 100_000.0, "elem", || {
        work.clone_from(&base);
        black_box(casc.all_reduce(&mut work));
    });

    suite.finish();
}
