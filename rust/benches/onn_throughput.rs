//! ONN forward throughput — the switch's compute hot path (L3 native
//! executor; the PJRT path is covered by `e2e_step`). Sweeps batch size
//! and scenario structure; reports words/s through the full
//! encode → P → ONN → snap → decode datapath.

use optinc::config::Scenario;
use optinc::onn::random_network;
use optinc::optinc::switch::{OnnMode, OptIncSwitch};
use optinc::util::bench::{black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn main() {
    let mut suite = BenchSuite::new("onn_throughput");

    // Raw MLP forward per scenario structure.
    for id in [1usize, 2, 4] {
        let sc = Scenario::table1(id).unwrap();
        let net = random_network(&sc.layers, id as u64);
        let batch = 1024usize;
        let mut rng = Pcg32::seeded(3);
        let x: Vec<f32> = (0..batch * sc.onn_inputs())
            .map(|_| rng.gen_range(13) as f32 * 0.25)
            .collect();
        let macs = (net.macs_per_sample() * batch) as f64;
        suite.bench_throughput(
            &format!("onn_fwd/s{id}/b{batch}"),
            macs,
            "MAC",
            || {
                black_box(net.forward(&x, batch));
            },
        );
    }

    // Full switch datapath (scenario 1), batch sweep.
    let sc = Scenario::table1(1).unwrap();
    for batch in [256usize, 1024, 4096, 16384] {
        let net = random_network(&sc.layers, 7);
        let mut sw = OptIncSwitch::new(sc.clone(), OnnMode::Native(net)).unwrap();
        let mut rng = Pcg32::seeded(batch as u64);
        let shards: Vec<Vec<u32>> = (0..4)
            .map(|_| (0..batch).map(|_| rng.gen_range(256)).collect())
            .collect();
        let views: Vec<&[u32]> = shards.iter().map(|s| s.as_slice()).collect();
        suite.bench_throughput(
            &format!("switch_native/b{batch}"),
            batch as f64,
            "word",
            || {
                black_box(sw.average_words(&views));
            },
        );
    }

    // Oracle switch (arithmetic floor — how fast the datapath itself is).
    let mut sw = OptIncSwitch::exact(sc);
    let mut rng = Pcg32::seeded(77);
    let batch = 16384usize;
    let shards: Vec<Vec<u32>> = (0..4)
        .map(|_| (0..batch).map(|_| rng.gen_range(256)).collect())
        .collect();
    let views: Vec<&[u32]> = shards.iter().map(|s| s.as_slice()).collect();
    suite.bench_throughput("switch_oracle/b16384", batch as f64, "word", || {
        black_box(sw.average_words(&views));
    });

    suite.finish();
}
