//! Native ONN training throughput — the hardware-aware trainer's hot
//! loop (`onn::train`). Measures optimizer steps/s and training
//! samples/s for unconstrained vs hardware-aware (projected) training,
//! isolates the reprojection overhead, and records the final held-out
//! relative error of a short hardware-aware run as a quality scalar.

use optinc::config::Scenario;
use optinc::onn::random_network;
use optinc::onn::train::{
    evaluate, train_for_scenario, AveragingDataset, HardwareMode, Optimizer, TrainConfig, Trainer,
};
use optinc::photonics::approx::project_weights_f32;
use optinc::photonics::mesh::MeshKind;
use optinc::photonics::noise::NoiseModel;
use optinc::util::bench::{black_box, BenchSuite};

fn bench_scenario() -> Scenario {
    // Reduced structure: big enough to exercise every code path
    // (multi-block projection, ReLU chain), small enough that the bench
    // finishes quickly even in quick mode.
    Scenario {
        id: 0,
        bits: 8,
        servers: 4,
        layers: vec![4, 32, 32, 4],
        approx_layers: vec![1, 2, 3],
    }
}

fn cfg(hardware: HardwareMode) -> TrainConfig {
    TrainConfig {
        steps: 0, // stepped manually below
        batch: 64,
        lr: 0.01,
        optimizer: Optimizer::adam(),
        hardware,
        seed: 1,
    }
}

fn main() {
    let mut suite = BenchSuite::new("train_onn");
    let sc = bench_scenario();

    // Optimizer-step throughput, unconstrained vs hardware-aware.
    for (name, hardware) in [
        ("plain", HardwareMode::Unconstrained),
        (
            "aware",
            HardwareMode::Aware {
                reproject_every: 1,
                noise: NoiseModel::new(0.01, 0.0, 0),
                approx_layers: vec![1, 2, 3],
                mesh: MeshKind::Dense,
            },
        ),
    ] {
        let c = cfg(hardware);
        let mut trainer = Trainer::new(random_network(&sc.layers, 3), c.clone()).unwrap();
        let mut data = AveragingDataset::new(&sc, 7);
        let (mut x, mut t) = (Vec::new(), Vec::new());
        data.sample_batch(c.batch, &mut x, &mut t);
        suite.bench_throughput(
            &format!("train_step/{name}/b{}", c.batch),
            c.batch as f64,
            "sample",
            || {
                black_box(trainer.train_step(&x, &t, c.batch));
            },
        );
    }

    // The projection operator alone (the hardware-aware overhead).
    for n in [16usize, 32, 64] {
        let net = random_network(&[n, n], 5);
        let mut w = net.layers[0].weight.clone();
        suite.bench(&format!("reproject/{n}x{n}"), || {
            project_weights_f32(&mut w, n, n);
            black_box(&w);
        });
    }

    // Quality scalar: held-out relative error after a short aware run
    // (tracks regressions in the training math, not just its speed).
    let quick = std::env::var("OPTINC_BENCH_QUICK").is_ok_and(|v| v == "1");
    let steps = if quick { 60 } else { 300 };
    let tcfg = TrainConfig {
        steps,
        ..cfg(HardwareMode::aware_default())
    };
    let (net, report) = train_for_scenario(&sc, &tcfg);
    let mut held = AveragingDataset::new(&sc, 99);
    suite.record_scalar("aware/tail_loss", report.tail_loss(20), "mse");
    suite.record_scalar("aware/heldout_rel_err", evaluate(&net, &mut held, 2048), "rel");

    suite.finish();
}
