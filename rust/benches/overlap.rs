//! Overlap bench: the reconfiguration-scheduling sweep behind
//! `BENCH_overlap.json` — per-strategy exposed / hidden / queued OCS
//! reconfiguration across fabric depths {2,3} and concurrent-job counts
//! {1,4} on the event backend. Times the wall-clock cost of one sweep
//! and records every cell's virtual-clock scalars so the trajectory
//! pins the `serial ≥ pipelined ≥ eager` exposed-wait ordering.
//! `-- --json` writes the `BENCH_overlap.json` artifact.

use optinc::experiments::overlap::{run as run_sweep, SweepConfig};
use optinc::util::bench::{arg_flag, black_box, BenchSuite};

fn main() {
    let json_mode = arg_flag("--json");
    let mut suite = if json_mode {
        BenchSuite::quick("overlap-event")
    } else {
        BenchSuite::new("overlap")
    };

    let cfg = SweepConfig::default();

    // Wall-clock: one full sweep (12 event-backend cells).
    suite.bench_throughput(
        "overlap_sweep/d2,3/j1,4/3-strategies",
        (cfg.depths.len() * cfg.jobs.len() * cfg.strategies.len()) as f64,
        "cell",
        || {
            let rows = run_sweep(&cfg).unwrap();
            black_box(rows.len());
        },
    );

    // Virtual-clock scalars: one row of scalars per sweep cell — the
    // numbers EXPERIMENTS.md §Overlap strategies quotes.
    let rows = run_sweep(&cfg).unwrap();
    for r in &rows {
        let key = format!("{}/d{}/j{}", r.strategy.name(), r.depth, r.jobs);
        suite.record_scalar(
            &format!("virtual_step/{key}"),
            r.mean_virtual_step_s * 1e6,
            "us",
        );
        suite.record_scalar(&format!("exposed/{key}"), r.mean_exposed_s * 1e6, "us");
        suite.record_scalar(&format!("hidden/{key}"), r.mean_hidden_s * 1e6, "us");
        suite.record_scalar(&format!("queued/{key}"), r.mean_queued_s * 1e6, "us");
        suite.record_scalar(
            &format!("steady_exposed/{key}"),
            r.steady_exposed_s * 1e6,
            "us",
        );
    }

    if json_mode {
        suite.finish_named("BENCH_overlap");
    } else {
        suite.finish();
    }
}
