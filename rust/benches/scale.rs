//! Scale bench: the event backend simulating 64 → 1024 servers through
//! a 3-level fabric, in one process. Times the wall-clock cost of the
//! simulation itself (can this laptop sweep 1024 servers?) and records
//! the virtual-clock scalars the sweep exists to measure — mean virtual
//! step time, mean per-step OCS reconfiguration-gate wait, and the
//! closed-form modeled communication time per step it is checked
//! against. `-- --json` writes the `BENCH_scale.json` trajectory
//! artifact.

use optinc::cluster::{Backend, Cluster, ClusterMetrics, Workload};
use optinc::collectives::fabric::{FabricAllReduce, FabricMode, FabricTopology};
use optinc::experiments::scale::{run as run_sweep, SweepConfig};
use optinc::util::bench::{arg_flag, black_box, BenchSuite};
use optinc::util::rng::Pcg32;

struct Synth {
    dim: usize,
}

impl Workload for Synth {
    fn grad(&mut self, step: usize, worker: usize) -> (Vec<f32>, f64) {
        let mut rng = Pcg32::new(0xBE_5C ^ ((step as u64) << 32), worker as u64);
        let g = (0..self.dim).map(|_| rng.normal() as f32 * 0.1).collect();
        (g, 0.0)
    }

    fn apply(&mut self, _step: usize, _worker: usize, _avg: &[f32]) {}
}

fn main() {
    let json_mode = arg_flag("--json");
    let mut suite = if json_mode {
        BenchSuite::quick("scale-event")
    } else {
        BenchSuite::new("scale")
    };

    // Wall-clock: one event-backend step per server count. The payload
    // shrinks in json/quick mode so CI stays fast; the server counts do
    // not — the whole point is the 1024-server row.
    let elements: usize = if json_mode { 8_192 } else { 65_536 };
    let chunk = (elements / 8).max(1);
    let servers: &[usize] = &[64, 256, 1024];
    for &n in servers {
        let topo = FabricTopology::for_workers_with_depth(n, 3).unwrap();
        let cluster = Cluster::new(n)
            .with_chunk_elems(chunk)
            .with_backend(Backend::Event)
            .with_seed(42);
        let mut fabric = FabricAllReduce::exact(8, &topo, FabricMode::Remainder).unwrap();
        suite.bench_throughput(
            &format!("event_step/{n}x{elements}/d3"),
            (n * elements) as f64,
            "elem",
            || {
                let mut metrics = ClusterMetrics::new("bench");
                let records = cluster
                    .run(1, |_| Synth { dim: elements }, &mut fabric, &mut metrics)
                    .unwrap();
                black_box(records[0].virtual_time_s);
            },
        );
    }

    // Virtual-clock scalars from the canonical sweep config — the
    // measured numbers EXPERIMENTS.md §Scale sweep quotes, tracked as
    // a trajectory in BENCH_scale.json.
    let cfg = SweepConfig {
        elements: 8_192,
        chunk: 1_024,
        ..SweepConfig::default()
    };
    let rows = run_sweep(&cfg).unwrap();
    for r in &rows {
        suite.record_scalar(
            &format!("virtual_step/{}x{}/d{}", r.servers, cfg.elements, cfg.levels),
            r.mean_virtual_step_s * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("modeled_comm/{}x{}/d{}", r.servers, cfg.elements, cfg.levels),
            r.mean_modeled_comm_s * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("reconfig_wait/{}x{}/d{}", r.servers, cfg.elements, cfg.levels),
            r.mean_virtual_reconfig_wait_s * 1e6,
            "us",
        );
        suite.record_scalar(
            &format!("wire_bytes/{}x{}/d{}", r.servers, cfg.elements, cfg.levels),
            r.wire_bytes_per_server as f64,
            "B",
        );
    }

    if json_mode {
        suite.finish_named("BENCH_scale");
    } else {
        suite.finish();
    }
}
