//! Fabric collective bench: wall-clock throughput of the streamed
//! multi-level cascade across a depth × fan-in sweep, plus the modeled
//! step-time scalars (hop latency + SWOT-style reconfiguration overlap)
//! and a cheap bit-exactness self-check against the flat quantized mean
//! on every swept configuration.

use optinc::collectives::engine::{ChunkedDriver, ReducePlan};
use optinc::collectives::fabric::{FabricAllReduce, FabricMode, FabricTopology};
use optinc::collectives::wire::packed_len;
use optinc::config::HardwareModel;
use optinc::quant::chunked_reference_mean;
use optinc::util::bench::{arg_flag, black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn shards(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.1).collect())
        .collect()
}

/// Flat reference on the whole-shard block scale (single chunk).
fn flat_reference(base: &[Vec<f32>]) -> Vec<f32> {
    chunked_reference_mean(base, usize::MAX, 8)
}

fn main() {
    // Artifact mode (`-- --json`): a reduced sweep at the quick config,
    // written to a pinned file for the CI perf-trajectory upload
    // alongside the allreduce bench's BENCH_wire.json.
    let json_mode = arg_flag("--json");
    let mut suite = if json_mode {
        BenchSuite::quick("fabric-wire")
    } else {
        BenchSuite::new("fabric")
    };
    let hw = HardwareModel::default();

    // Depth × fan-in sweep. Worker counts are capped so the deepest
    // trees stay laptop-sized; capacity is reported alongside. The
    // artifact mode trims the sweep to one fan-in, two depths.
    let fan_ins: &[usize] = if json_mode { &[4] } else { &[2, 4, 16] };
    let max_depth: usize = if json_mode { 2 } else { 3 };
    for &fan_in in fan_ins {
        for depth in 1..=max_depth {
            let topo = FabricTopology::uniform(fan_in, depth).unwrap();
            let workers = topo.capacity().min(64);
            let len = 20_000usize;
            let base = shards(workers, len, (fan_in * 10 + depth) as u64);

            // Bit-exactness self-check (small payload, one chunk).
            {
                let mut fabric =
                    FabricAllReduce::exact(8, &topo, FabricMode::Remainder).unwrap();
                let small: Vec<Vec<f32>> =
                    base.iter().map(|s| s[..256].to_vec()).collect();
                let want = flat_reference(&small);
                let mut work = small.clone();
                let mut driver = ChunkedDriver::new(usize::MAX);
                driver.all_reduce(&mut fabric, &mut work);
                assert_eq!(
                    work[0], want,
                    "f{fan_in} d{depth}: remainder fabric must match the flat mean"
                );
            }

            let mut fabric = FabricAllReduce::exact(8, &topo, FabricMode::Remainder).unwrap();
            let mut driver = ChunkedDriver::new(len / 16);
            let mut work = base.clone();
            suite.bench_throughput(
                &format!("fabric/f{fan_in}/d{depth}/{workers}x{len}"),
                (workers * len) as f64,
                "elem",
                || {
                    work.clone_from(&base);
                    black_box(driver.all_reduce(&mut fabric, &mut work));
                },
            );

            // Modeled step time: monolithic vs streamed — the streamed
            // schedule hides both the return leg and the per-level OCS
            // reconfiguration (SWOT overlap).
            let mut mono = base.clone();
            let mono_stats = ChunkedDriver::new(usize::MAX).all_reduce(&mut fabric, &mut mono);
            let mut piped = base.clone();
            let piped_stats = driver.all_reduce(&mut fabric, &mut piped);
            let t_mono = mono_stats.modeled_step_time_s(&hw);
            let t_piped = piped_stats.modeled_step_time_s(&hw);
            suite.record_scalar(
                &format!("modeled_step/f{fan_in}/d{depth}/monolithic"),
                t_mono * 1e6,
                "us",
            );
            suite.record_scalar(
                &format!("modeled_step/f{fan_in}/d{depth}/pipelined"),
                t_piped * 1e6,
                "us",
            );
            suite.record_scalar(
                &format!("modeled_step/f{fan_in}/d{depth}/reconfig_exposed"),
                piped_stats.exposed_reconfig_s(&hw) * 1e6,
                "us",
            );
            assert!(
                t_piped < t_mono,
                "f{fan_in} d{depth}: pipelined {t_piped} must beat monolithic {t_mono}"
            );

            // Packed wire volume: the fabric's access links carry
            // B-bit words, not f32 — the scalar CI tracks.
            suite.record_scalar(
                &format!("wire/f{fan_in}/d{depth}/packed_bytes_per_server"),
                packed_len(len, 8) as f64,
                "B",
            );
            suite.record_scalar(
                &format!("wire/f{fan_in}/d{depth}/f32_bytes_per_server"),
                (len * 4) as f64,
                "B",
            );
        }
    }

    // Reduce-threads sweep: the depth-2 fabric's end-to-end stream at
    // 1/2/4/8 range-splitting threads (per-leaf unpack + every level
    // switch's word accumulation). Threshold forced to 1 so the chosen
    // thread count is what actually runs; outputs are bit-identical at
    // every setting, so only wall-clock moves.
    {
        let topo = FabricTopology::uniform(4, 2).unwrap();
        let workers = 16usize;
        let len = 100_000usize;
        let base = shards(workers, len, 0x7EADC);
        let mut t1 = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let mut fabric = FabricAllReduce::exact(8, &topo, FabricMode::Remainder).unwrap();
            fabric.set_reduce_plan(ReducePlan::with_threads(threads).with_threshold(1));
            let mut driver = ChunkedDriver::new(len / 8);
            let mut work = base.clone();
            let t = suite
                .bench_throughput(
                    &format!("fabric_reduce/t{threads}/{workers}x{len}"),
                    (workers * len) as f64,
                    "elem",
                    || {
                        work.clone_from(&base);
                        black_box(driver.all_reduce(&mut fabric, &mut work));
                    },
                )
                .mean_s();
            if threads == 1 {
                t1 = t;
            } else {
                suite.record_scalar(
                    &format!("fabric_reduce/speedup_measured/t{threads}"),
                    t1 / t,
                    "x",
                );
            }
        }
    }

    if json_mode {
        suite.finish_named("BENCH_wire_fabric");
    } else {
        suite.finish();
    }
}
