//! Bench/regeneration target for Fig. 7b: the modeled per-step latency
//! breakdown, asserted to preserve the paper's shape (>25% reduction for
//! ResNet50, ~17% for the LLaMA-based network), plus the N-scaling curve.

use optinc::config::HardwareModel;
use optinc::experiments::fig7b;
use optinc::latency::{LatencyBreakdown, WorkloadModel};
use optinc::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("fig7b_latency");
    let hw = HardwareModel::default();

    for b in fig7b::breakdowns(4) {
        let t = b.ring_total();
        let tag = if b.workload.starts_with("ResNet") { "resnet50" } else { "llama" };
        suite.record_scalar(&format!("{tag}/compute_frac"), b.compute_s / t, "of ring total");
        suite.record_scalar(&format!("{tag}/ring_comm_frac"), b.ring_comm_s / t, "of ring total");
        suite.record_scalar(&format!("{tag}/optinc_total"), b.optinc_total() / t, "of ring total");
        suite.record_scalar(&format!("{tag}/reduction"), b.reduction(), "fraction");
    }
    let bs = fig7b::breakdowns(4);
    assert!(bs[0].reduction() > 0.25, "paper: ResNet reduction > 25%");
    assert!(
        (0.10..0.30).contains(&bs[1].reduction()),
        "paper: LLaMA reduction ≈ 17%"
    );

    // Server-count scaling (the paper's "increasing trend" remark).
    for n in [4usize, 8, 16, 32] {
        let b = LatencyBreakdown::new(&WorkloadModel::resnet50_default(), &hw, n);
        suite.record_scalar(&format!("scaling/resnet50_N{n}_reduction"), b.reduction(), "fraction");
    }

    suite.finish();
}
