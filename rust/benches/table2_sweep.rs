//! Bench/regeneration target for Table II: the scenario-4 approximation
//! sweep (area column analytically; the ONN-accuracy columns come from
//! the training metrics, printed by `optinc-repro table2`), plus the cost
//! of the Σ·U approximation itself at the paper's block sizes.

use optinc::config::Scenario;
use optinc::linalg::random_mat;
use optinc::photonics::{approx::ApproxMatrix, area};
use optinc::util::bench::{black_box, BenchSuite};
use optinc::util::rng::Pcg32;

fn main() {
    let mut suite = BenchSuite::new("table2_sweep");

    let paper = [0.493, 0.479, 0.474, 0.437, 0.422];
    for ((label, sc), want) in Scenario::table2_variants().into_iter().zip(paper) {
        let got = area::area_ratio(&sc);
        suite.record_scalar(&format!("layers[{label}]/area_ratio"), got, "ratio");
        assert!(
            (got - want).abs() < 0.002,
            "layer set {label} diverged from paper: {got} vs {want}"
        );
    }

    // Approximation (SVD + Procrustes) cost per square block size —
    // the offline compile-path cost the paper's scheme adds.
    let mut rng = Pcg32::seeded(5);
    for s in [64usize, 128, 256] {
        let w = random_mat(&mut rng, s, s);
        suite.bench(&format!("approx_block/{s}x{s}"), || {
            black_box(ApproxMatrix::from_dense(&w));
        });
    }

    // Approximation error distribution on random weights (context for
    // why hardware-aware training is needed).
    let w = random_mat(&mut rng, 128, 128);
    let a = ApproxMatrix::from_dense(&w);
    suite.record_scalar("approx_block/128_rel_error", a.relative_error(&w), "rel");

    suite.finish();
}
