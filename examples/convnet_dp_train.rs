//! Fig. 7a workload #1 (substituted): data-parallel training of the small
//! residual ConvNet on synthetic 32×32 10-class images, through the same
//! three-layer path as `llama_dp_train`.
//!
//! Run: `make artifacts && cargo run --release --example convnet_dp_train -- [steps]`

use std::sync::Arc;

use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::AllReduce;
use optinc::config::Scenario;
use optinc::optinc::error_model::ErrorModel;
use optinc::optinc::switch::OptIncSwitch;
use optinc::runtime::Runtime;
use optinc::train::{DpTrainer, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(120);
    let workers = 4;
    let rt = Arc::new(Runtime::new()?);
    println!("PJRT platform: {}", rt.platform());

    let mut run = |name: &str, coll: &mut dyn AllReduce| -> anyhow::Result<(f64, f64)> {
        let mut t = DpTrainer::new(rt.clone(), WorkloadKind::Cnn)?;
        println!(
            "\n== {name}: {} params, {} workers, batch {}, {} steps ==",
            t.param_count(),
            workers,
            t.batch,
            steps
        );
        let logs = t.run(workers, steps, coll, 99, 20)?;
        let tail = &logs[logs.len().saturating_sub(20)..];
        let loss = tail.iter().map(|l| l.mean_loss).sum::<f64>() / tail.len() as f64;
        let acc = tail.iter().map(|l| l.aux).sum::<f64>() / tail.len() as f64;
        println!("{name}: tail loss {loss:.4}, tail accuracy {acc:.3}");
        Ok((loss, acc))
    };

    let sc = Scenario::table1(4)?;
    let (bl, ba) = run("ring baseline", &mut RingAllReduce::new())?;
    let mut oi = OptIncAllReduce::exact(sc.clone(), 5);
    let (ol, oa) = run("optinc", &mut oi)?;
    let em = ErrorModel::paper_table2(1, 6);
    let mut oe = OptIncAllReduce::new(OptIncSwitch::exact(sc), em, 6);
    let (el, ea) = run("optinc + errors", &mut oe)?;

    println!("\nFig. 7a (convnet): baseline acc {ba:.3} | optinc {oa:.3} (Δ{:+.3}) | +errors {ea:.3} (Δ{:+.3})",
        oa - ba, ea - ba);
    println!("losses: {bl:.4} | {ol:.4} | {el:.4}");
    println!("(paper: ResNet50/CIFAR-100 accuracy −0.03 pp from quantization, −0.55 pp with errors)");
    Ok(())
}
