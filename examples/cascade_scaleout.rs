//! §III-C scale-out: serving 16 servers by cascading five 4-port OptINCs
//! in two levels (Fig. 5), comparing the naive two-level quantization
//! (eq. 9) against the remainder-preserving scheme (eq. 10) and the flat
//! 16-port switch.
//!
//! Run: `cargo run --release --example cascade_scaleout`

use optinc::collectives::engine::ChunkedDriver;
use optinc::collectives::fabric::{FabricAllReduce, FabricMode, FabricTopology};
use optinc::collectives::hierarchical::HierarchicalOptInc;
use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::{exact_mean, AllReduce};
use optinc::config::{HardwareModel, Scenario};
use optinc::optinc::cascade::CascadeMode;
use optinc::photonics::area;
use optinc::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let elements = 50_000;
    let mut rng = Pcg32::seeded(2024);
    let shards: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..elements).map(|_| rng.normal() as f32 * 0.05).collect())
        .collect();
    let want = exact_mean(&shards);
    let mae = |xs: &[f32]| -> f64 {
        xs.iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / want.len() as f64
    };

    let sc4 = Scenario::table1(1)?;
    let sc16 = Scenario::table1(3)?;

    // Flat 16-port switch (scenario 3) as the reference.
    let mut flat = OptIncAllReduce::exact(sc16, 1);
    let mut a = shards.clone();
    flat.all_reduce(&mut a);

    // Cascade, naive quantize-at-both-levels (eq. 9).
    let mut basic = HierarchicalOptInc::new(sc4.clone(), CascadeMode::Basic);
    let mut b = shards.clone();
    basic.all_reduce(&mut b);

    // Cascade with the decimal remainder carried through (eq. 10).
    let mut rem = HierarchicalOptInc::new(sc4.clone(), CascadeMode::Remainder);
    let mut c = shards.clone();
    rem.all_reduce(&mut c);

    println!("16-server aggregation, {elements} gradient elements:");
    println!("  flat 16-port switch        : MAE {:.3e}", mae(&a[0]));
    println!("  cascade basic   (eq. 9)    : MAE {:.3e}", mae(&b[0]));
    println!("  cascade remainder (eq. 10) : MAE {:.3e}", mae(&c[0]));
    let agree = a[0].iter().zip(&c[0]).filter(|(x, y)| x == y).count();
    println!(
        "  remainder vs flat agreement: {}/{} elements ({:.2}%)",
        agree,
        elements,
        100.0 * agree as f64 / elements as f64
    );

    // Hardware overhead of the expanded ONN (§IV last experiment).
    let base = Scenario::table1(1)?;
    let exp = Scenario::cascade_expanded();
    println!(
        "\nexpanded ONN structure {:?}",
        exp.layers
    );
    println!(
        "  MZIs: base {} → expanded {} (+{:.1}%, paper: ~10.5%)",
        area::scenario_mzis(&base, true),
        area::scenario_mzis(&exp, true),
        (area::scenario_mzis(&exp, true) as f64 / area::scenario_mzis(&base, true) as f64 - 1.0)
            * 100.0
    );

    // Arbitrary-depth streamed fabric: 64 servers through three levels
    // of 4-port switches — 16× one switch's port count — chunked, with
    // the remainder (eq. 10) forwarded at every level.
    let workers = 64usize;
    let mut rng = Pcg32::seeded(7);
    let big: Vec<Vec<f32>> = (0..workers)
        .map(|_| (0..elements).map(|_| rng.normal() as f32 * 0.05).collect())
        .collect();
    let topo = FabricTopology::for_workers(4, workers)?;
    let mut fabric = FabricAllReduce::exact(8, &topo, FabricMode::Remainder)?;
    let mut driver = ChunkedDriver::new(elements / 16);
    let mut out = big.clone();
    let stats = driver.all_reduce(&mut fabric, &mut out);
    let hw = HardwareModel::default();
    println!(
        "\nstreamed fabric: {workers} servers, {} levels of 4-port switches {:?}",
        topo.depth(),
        topo.switch_counts(workers)
    );
    println!(
        "  {} chunks, {} switch hops, modeled step {:.1} µs \
         (exposed reconfiguration {:.2} µs of {:.0} µs)",
        stats.chunks,
        stats.levels,
        stats.modeled_step_time_s(&hw) * 1e6,
        stats.exposed_reconfig_s(&hw) * 1e6,
        (stats.levels - 1) as f64 * hw.ocs_reconfig_s * 1e6,
    );
    let big_want = exact_mean(&big);
    let fabric_mae = out[0]
        .iter()
        .zip(&big_want)
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / big_want.len() as f64;
    println!("  MAE vs exact 64-server mean: {fabric_mae:.3e} (quantization floor only)");
    Ok(())
}
