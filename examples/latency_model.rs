//! Fig. 7b explorer: sweep server counts and batch sizes through the
//! analytic latency model to see where OptINC's single-traversal
//! collective pays off.
//!
//! Run: `cargo run --release --example latency_model`

use optinc::config::HardwareModel;
use optinc::latency::{LatencyBreakdown, WorkloadModel};

fn main() {
    let hw = HardwareModel::default();
    println!(
        "hardware: {:.0} TFLOPs × {:.1} util, {}×{:.0} Gb/s transceivers/server",
        hw.gpu_flops / 1e12,
        hw.gpu_utilization,
        hw.transceivers,
        hw.transceiver_bps / 1e9
    );

    println!("\n== Fig. 7b defaults (N = 4) ==");
    for w in [WorkloadModel::resnet50_default(), WorkloadModel::llama_default()] {
        let b = LatencyBreakdown::new(&w, &hw, 4);
        let t = b.ring_total();
        println!(
            "{:<24} compute {:>6.1}% | ring comm {:>6.1}% | optinc total {:>6.1}% | reduction {:>5.1}%",
            b.workload,
            100.0 * b.compute_s / t,
            100.0 * b.ring_comm_s / t,
            100.0 * b.optinc_total() / t,
            100.0 * b.reduction()
        );
    }

    println!("\n== scaling with server count (ResNet50) ==");
    println!("{:>8} {:>12} {:>12} {:>12}", "N", "ring comm", "optinc comm", "reduction");
    for n in [2usize, 4, 8, 16, 32, 64] {
        let b = LatencyBreakdown::new(&WorkloadModel::resnet50_default(), &hw, n);
        println!(
            "{:>8} {:>10.1}µs {:>10.1}µs {:>11.1}%",
            n,
            b.ring_comm_s * 1e6,
            b.optinc_comm_s * 1e6,
            b.reduction() * 100.0
        );
    }

    println!("\n== batch-size sensitivity (LLaMA tokens/server/step, N = 4) ==");
    println!("{:>10} {:>12} {:>12}", "tokens", "comm share", "reduction");
    for tokens in [64usize, 128, 176, 256, 512, 1024, 4096] {
        let b = LatencyBreakdown::new(&WorkloadModel::llama_wiki(tokens), &hw, 4);
        println!(
            "{:>10} {:>11.1}% {:>11.1}%",
            tokens,
            100.0 * b.ring_comm_s / b.ring_total(),
            100.0 * b.reduction()
        );
    }
    println!("\n(the paper's bars correspond to the strong-scaling regime; see EXPERIMENTS.md)");
}
