//! End-to-end driver (EXPERIMENTS.md §E2E): data-parallel training of the
//! LLaMA-style LM through the full three-layer stack.
//!
//! Per step, each of the 4 workers executes the AOT-lowered JAX train-step
//! (`artifacts/lm_grad_b8.hlo.txt`) via PJRT, the gradients are averaged
//! by the configured collective (ring baseline, OptINC quantized, or
//! OptINC + Table II error injection), and the AOT Adam step updates the
//! flat parameter vector. Python never runs.
//!
//! Run: `make artifacts && cargo run --release --example llama_dp_train -- [steps] [collective]`
//!   collective ∈ ring | optinc | optinc-err (default: compares all three)

use std::sync::Arc;

use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::AllReduce;
use optinc::config::Scenario;
use optinc::optinc::error_model::ErrorModel;
use optinc::optinc::switch::OptIncSwitch;
use optinc::runtime::Runtime;
use optinc::train::{tail_loss, DpTrainer, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(200);
    let which = args.get(1).cloned().unwrap_or_else(|| "all".to_string());
    let workers = 4;
    let rt = Arc::new(Runtime::new()?);
    println!("PJRT platform: {}", rt.platform());

    let mut run = |name: &str, coll: &mut dyn AllReduce| -> anyhow::Result<(f64, f64)> {
        let mut t = DpTrainer::new(rt.clone(), WorkloadKind::Lm)?;
        println!(
            "\n== {name}: {} params, {} workers, batch {}×seq {}, {} steps ==",
            t.param_count(),
            workers,
            t.batch,
            t.seq,
            steps
        );
        let t0 = std::time::Instant::now();
        let logs = t.run(workers, steps, coll, 1234, 20)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = tail_loss(&logs[..logs.len().min(10)], 10);
        let last = tail_loss(&logs, 20);
        println!(
            "{name}: loss {first:.4} → {last:.4} over {steps} steps ({:.2} s/step)",
            wall / steps as f64
        );
        Ok((first, last))
    };

    let sc = Scenario::table1(4)?; // 16-bit quantization path
    let mut results: Vec<(String, f64)> = Vec::new();

    if which == "all" || which == "ring" {
        let (_, l) = run("ring (exact fp32 baseline)", &mut RingAllReduce::new())?;
        results.push(("ring".into(), l));
    }
    if which == "all" || which == "optinc" {
        let mut c = OptIncAllReduce::exact(sc.clone(), 7);
        let (_, l) = run("optinc (16-bit quantized)", &mut c)?;
        results.push(("optinc".into(), l));
    }
    if which == "all" || which == "optinc-err" {
        let em = ErrorModel::paper_table2(1, 11);
        let mut c = OptIncAllReduce::new(OptIncSwitch::exact(sc), em, 11);
        let (_, l) = run("optinc + Table II errors", &mut c)?;
        results.push(("optinc-err".into(), l));
    }

    if results.len() > 1 {
        println!("\nFig. 7a summary (tail-20 mean loss):");
        let base = results[0].1;
        for (name, l) in &results {
            println!("  {name:<12} {l:.4}  (Δ vs ring {:+.4})", l - base);
        }
        println!("(paper: Δ ≈ +0.018 from quantization, ≈ +0.02 with injected errors)");
    }
    Ok(())
}
