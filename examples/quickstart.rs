//! Quickstart: the OptINC switch in five minutes.
//!
//! Builds a 4-server, 8-bit OptINC switch (exact-oracle ONN — no trained
//! artifacts needed), pushes a gradient batch through it, and compares
//! against ring all-reduce on the same shards: same result, one round
//! instead of six, 1.0× payload instead of 1.5×.
//!
//! Run: `cargo run --release --example quickstart`

use optinc::collectives::optinc::OptIncAllReduce;
use optinc::collectives::ring::RingAllReduce;
use optinc::collectives::{exact_mean, AllReduce};
use optinc::config::{HardwareModel, Scenario};
use optinc::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // 1. A Table I scenario: 8-bit gradients, 4 servers, K=4 ONN inputs.
    let sc = Scenario::table1(1)?;
    println!(
        "scenario 1: B={} bits, N={} servers, ONN {:?} ({} PAM4 symbols/word)",
        sc.bits,
        sc.servers,
        sc.layers,
        sc.symbols()
    );

    // 2. Four workers with random local gradients.
    let mut rng = Pcg32::seeded(42);
    let elements = 100_000;
    let shards: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..elements).map(|_| rng.normal() as f32 * 0.05).collect())
        .collect();
    let want = exact_mean(&shards);

    // 3. Baseline: ring all-reduce (exact fp32, 2(N−1) rounds).
    let mut ring_shards = shards.clone();
    let ring_stats = RingAllReduce::new().all_reduce(&mut ring_shards);

    // 4. OptINC: quantize → one switch traversal → dequantize.
    let mut oi_shards = shards.clone();
    let mut oi = OptIncAllReduce::exact(sc, 7);
    let oi_stats = oi.all_reduce(&mut oi_shards);

    // 5. Compare.
    let max_err = |xs: &[f32]| {
        xs.iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max)
    };
    let hw = HardwareModel::default();
    println!(
        "\n{:<12} {:>8} {:>14} {:>12} {:>12}",
        "collective", "rounds", "bytes/server", "norm comm", "max |err|"
    );
    println!(
        "{:<12} {:>8} {:>14} {:>12.3} {:>12.2e}",
        "ring",
        ring_stats.rounds,
        ring_stats.bytes_sent_per_server,
        ring_stats.normalized_comm(4.0),
        max_err(&ring_shards[0])
    );
    println!(
        "{:<12} {:>8} {:>14} {:>12.3} {:>12.2e}",
        "optinc",
        oi_stats.rounds,
        oi_stats.bytes_sent_per_server,
        oi_stats.normalized_comm(1.0),
        max_err(&oi_shards[0])
    );
    println!(
        "\nmodeled comm time on paper hardware: ring {:.1} µs vs optinc {:.1} µs",
        ring_stats.modeled_time_s(&hw) * 1e6,
        oi_stats.modeled_time_s(&hw) * 1e6
    );
    println!("(OptINC's error is the 8-bit quantization floor — see scenario 4 for 16-bit)");
    Ok(())
}
